//! Parallelism planner over the Shared Super-Model (§3.2).
//!
//! The paper hands the fused SSM to "existing planning frameworks"
//! (Megatron-LM, Metis) whose layer-wise profiling internalizes adapter
//! heterogeneity. No such framework exists in this Rust world, so this
//! module implements the part those planners contribute:
//!
//! 1. per-layer cost profiles from the SSM (+ the Kernel Fuser's adapter
//!    execution model),
//! 2. a dynamic-programming pipeline partitioner (contiguous layers →
//!    stages, minimizing the bottleneck stage),
//! 3. tensor-parallel degree selection with memory-feasibility checks,
//! 4. 1F1B microbatch schedule + bubble accounting,
//! 5. the Eq.-1 nano-batch overlap applied to the step's comm/comp split.
//!
//! Output is a [`ParallelPlan`] with the predicted step time, per-GPU
//! memory, utilization, and the comm/comp decomposition the scheduler's
//! throughput predictor T̂(G) consumes.

use crate::cluster::{Allocation, ClusterSpec};
use crate::kernelsim::overlap;
use crate::kernelsim::tile::{adapter_exec_time, AdapterLoad};
use crate::model::cost::memory_of;
use crate::model::arch::LoraSpec;
use crate::ssm::Ssm;

/// One pipeline stage: a contiguous slice of the SSM's layer chain.
#[derive(Debug, Clone, PartialEq)]
pub struct Stage {
    /// [begin, end) indices into the SSM layer chain (0 = embed,
    /// 1..=L = transformer layers, L+1 = head)
    pub begin: usize,
    pub end: usize,
    /// full-batch compute seconds on this stage (at chosen tp, scaled
    /// by the stage's hardware-tier compute multiplier)
    pub compute_s: f64,
}

/// A complete execution plan for one fused group.
#[derive(Debug, Clone, PartialEq)]
pub struct ParallelPlan {
    pub pp: usize,
    pub tp: usize,
    pub n_microbatches: usize,
    pub stages: Vec<Stage>,
    /// end-to-end step time (seconds), including pipeline bubble and
    /// nano-batch-overlapped communication
    pub step_time_s: f64,
    /// total per-step compute seconds (bottleneck path)
    pub comp_s: f64,
    /// total per-step communication seconds (TP allreduce + stage p2p)
    pub comm_s: f64,
    /// 1F1B bubble fraction (S-1)/(M+S-1)
    pub bubble_frac: f64,
    /// peak bytes per GPU
    pub mem_per_gpu: f64,
    /// useful FLOPs / (gpus * peak * step_time) — the Fig.-6a metric
    pub compute_util: f64,
    /// nano-batch count used for the overlap (1 when fuser disabled)
    pub n_nano: usize,
}

#[derive(Debug, Clone, PartialEq)]
pub enum PlanError {
    NoGpus,
    OutOfMemory { need: f64, have: f64 },
}

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlanError::NoGpus => write!(f, "allocation has no GPUs"),
            PlanError::OutOfMemory { need, have } => write!(
                f,
                "plan infeasible: needs {:.1} GiB/GPU, have {:.1} GiB",
                need / 1e9,
                have / 1e9
            ),
        }
    }
}

impl std::error::Error for PlanError {}

/// Planner knobs.
#[derive(Debug, Clone)]
pub struct PlanOptions {
    /// use the fused LoRA kernel model (§3.3) for adapter branches
    pub fused_kernel: bool,
    /// apply nano-batch overlap with this N (the simulator feeds the
    /// AIMD-controlled value; `None` = pick the oracle-best fixed N,
    /// used by the ablation benches)
    pub n_nano: Option<usize>,
    pub n_nano_max: usize,
}

impl Default for PlanOptions {
    fn default() -> Self {
        PlanOptions {
            fused_kernel: true,
            n_nano: None,
            n_nano_max: 64,
        }
    }
}

/// Derive the execution plan for `ssm` on `alloc`.
pub fn plan(
    ssm: &Ssm,
    alloc: &Allocation,
    spec: &ClusterSpec,
    opts: &PlanOptions,
) -> Result<ParallelPlan, PlanError> {
    let n = alloc.n_gpus();
    if n == 0 {
        return Err(PlanError::NoGpus);
    }
    let mut best: Option<ParallelPlan> = None;
    let mut any_oom: Option<PlanError> = None;
    for (pp, tp) in factorizations(n, ssm.arch.n_layers + 2) {
        match plan_fixed(ssm, alloc, spec, opts, pp, tp) {
            Ok(p) => {
                if best
                    .as_ref()
                    .map_or(true, |b| p.step_time_s < b.step_time_s)
                {
                    best = Some(p);
                }
            }
            Err(e @ PlanError::OutOfMemory { .. }) => any_oom = Some(e),
            Err(e) => return Err(e),
        }
    }
    best.ok_or_else(|| any_oom.unwrap_or(PlanError::NoGpus))
}

/// What a plan may *legally* depend on — the cache-key contract the
/// predictor's shape-level plan cache is built on (DESIGN.md
/// §Performance):
///
/// 1. the fused model's content: base architecture plus the *ordered*
///    per-adapter `(rank, batch, seq)` sequence (ordered, not a
///    multiset — f64 accumulation over the adapter branches is not
///    associative in bits, so two orders of the same adapters may
///    produce different low-order bits);
/// 2. the allocation's **node-equality pattern**: every bandwidth
///    query ([`ClusterSpec::bandwidth`], tier latencies,
///    `spans_nodes`) depends only on whether two GPUs share a node,
///    never on *which* physical node or local GPU index they occupy;
/// 3. the allocation's **hardware-tier pattern**: on mixed fleets the
///    per-stage compute weighting, bandwidth scaling and memory check
///    all read the per-GPU tier multipliers, so the key carries the
///    first-appearance-relabeled tier labels *plus* the multiplier
///    bit-patterns of the tiers touched, in first-appearance order
///    (labels alone would collapse different generations that happen
///    to pattern-match). Allocations touching only reference tiers
///    canonicalize to empty tier components, so homogeneous fleets
///    key — and cache — exactly as before;
/// 4. the allocation's **topology pattern**: on non-flat topologies
///    the bandwidth and latency queries additionally depend on
///    whether two GPUs share a rack or a region, so the key carries
///    first-appearance-relabeled rack and region labels per GPU plus
///    the topology multiplier bit-patterns. Flat topologies
///    canonicalize to empty components, so pre-topology keys — and
///    cached plans — are untouched;
/// 5. the [`PlanOptions`] and the (per-predictor, fixed)
///    [`ClusterSpec`];
/// 6. the **hole pattern** of the nodes the allocation touches: when
///    single-GPU faults have holed devices out of a touched node, the
///    key carries that node's *surviving* GPU count per allocation
///    slot, so plans consulted while a hole is open can never be
///    served to (or from) the hole-free shape of the same gang. The
///    component is empty whenever every touched node is hole-free —
///    in particular on fleets that never see a GPU fault — so
///    pre-hole keys and cached plans are untouched.
///
/// [`PlanShapeKey`] captures exactly these: two (ssm, alloc) pairs with
/// equal keys are guaranteed bit-identical [`plan`] outputs, so probing
/// the same group shape on different physical nodes — the dominant
/// pattern in binary-cut partner search and `allocate_avoiding`
/// fallbacks — can be served from cache.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PlanShapeKey {
    /// base model name (uniquely determines the [`crate::model::arch::ModelArch`])
    arch: String,
    /// ordered adapter content: (rank, batch_size, seq_len) per job
    adapters: Vec<(usize, usize, usize)>,
    /// canonical node pattern: one label per GPU in allocation order,
    /// nodes relabeled by first appearance ([`alloc_shape`])
    shape: Vec<u32>,
    /// canonical tier pattern: one tier label per GPU in allocation
    /// order, tiers relabeled by first appearance (empty when every
    /// touched tier is the reference)
    tier_shape: Vec<u32>,
    /// (compute, bw, mem) multiplier bit-patterns of the touched
    /// tiers, in first-appearance order (empty when all-reference)
    tier_table: Vec<(u64, u64, u64)>,
    /// canonical rack pattern: one rack label per GPU in allocation
    /// order, racks relabeled by first appearance (empty on flat
    /// topologies)
    rack_shape: Vec<u32>,
    /// canonical region pattern, relabeled like `rack_shape` (empty
    /// on flat topologies)
    region_shape: Vec<u32>,
    /// bit-patterns of (rack_bw, region_bw, rack_latency_s,
    /// region_latency_s) (empty on flat topologies)
    topo_table: Vec<u64>,
    /// surviving-GPU count of the hosting node, one entry per GPU in
    /// allocation order (empty whenever every touched node is
    /// hole-free — the byte-freedom gate for fleets without GPU
    /// faults)
    hole_shape: Vec<u32>,
    /// the [`PlanOptions`] fields, hashed structurally
    opts: (bool, Option<usize>, usize),
}

impl PlanShapeKey {
    /// The canonical shape key of planning `ssm` on `alloc` under
    /// `opts`, on a fleet described by `spec` with no holed GPUs.
    pub fn of(
        ssm: &Ssm,
        alloc: &Allocation,
        spec: &ClusterSpec,
        opts: &PlanOptions,
    ) -> PlanShapeKey {
        PlanShapeKey::of_with_holes(ssm, alloc, spec, &[], opts)
    }

    /// [`PlanShapeKey::of`] on a fleet where `holes[node]` devices of
    /// each node are individually failed. An empty slice (or all
    /// zeros, or holes only on untouched nodes) keys identically to
    /// `of` — bit-for-bit, component-for-component.
    pub fn of_with_holes(
        ssm: &Ssm,
        alloc: &Allocation,
        spec: &ClusterSpec,
        holes: &[u32],
        opts: &PlanOptions,
    ) -> PlanShapeKey {
        let mut tier_shape = Vec::with_capacity(alloc.gpus.len());
        let mut seen: Vec<usize> = vec![]; // tier indices, 1st-appear
        let mut all_reference = true;
        for g in &alloc.gpus {
            let ti = spec.tier_index(g.node);
            all_reference &= spec.tiers[ti].is_reference();
            let label = match seen.iter().position(|&t| t == ti) {
                Some(l) => l as u32,
                None => {
                    seen.push(ti);
                    (seen.len() - 1) as u32
                }
            };
            tier_shape.push(label);
        }
        let (tier_shape, tier_table) = if all_reference {
            (vec![], vec![])
        } else {
            let table = seen
                .iter()
                .map(|&ti| {
                    let t = &spec.tiers[ti];
                    (
                        t.compute_mult.to_bits(),
                        t.bw_mult.to_bits(),
                        t.mem_mult.to_bits(),
                    )
                })
                .collect();
            (tier_shape, table)
        };
        let (rack_shape, region_shape, topo_table) =
            if spec.topology.is_flat() {
                (vec![], vec![], vec![])
            } else {
                let relabel = |of: &dyn Fn(usize) -> usize| {
                    let mut seen: Vec<usize> = vec![];
                    alloc
                        .gpus
                        .iter()
                        .map(|g| {
                            let v = of(g.node);
                            match seen.iter().position(|&x| x == v) {
                                Some(l) => l as u32,
                                None => {
                                    seen.push(v);
                                    (seen.len() - 1) as u32
                                }
                            }
                        })
                        .collect::<Vec<u32>>()
                };
                let t = &spec.topology;
                (
                    relabel(&|n| spec.rack_of(n)),
                    relabel(&|n| spec.region_of(n)),
                    vec![
                        t.rack_bw.to_bits(),
                        t.region_bw.to_bits(),
                        t.rack_latency_s.to_bits(),
                        t.region_latency_s.to_bits(),
                    ],
                )
            };
        let hole = |node: usize| holes.get(node).copied().unwrap_or(0);
        let hole_shape: Vec<u32> =
            if alloc.gpus.iter().all(|g| hole(g.node) == 0) {
                vec![]
            } else {
                let gpn = spec.gpus_per_node as u32;
                alloc
                    .gpus
                    .iter()
                    .map(|g| gpn - hole(g.node))
                    .collect()
            };
        PlanShapeKey {
            arch: ssm.arch.name.clone(),
            adapters: ssm
                .adapters
                .iter()
                .map(|a| (a.rank, a.batch_size, a.seq_len))
                .collect(),
            shape: alloc_shape(alloc),
            tier_shape,
            tier_table,
            rack_shape,
            region_shape,
            topo_table,
            hole_shape,
            opts: (opts.fused_kernel, opts.n_nano, opts.n_nano_max),
        }
    }
}

/// Canonical node pattern of an allocation: node ids relabeled by
/// first appearance, one entry per GPU in allocation order. Two
/// allocations with equal patterns are indistinguishable to the
/// planner — `[n5,n5,n9] → [0,0,1]` and `[n2,n2,n7] → [0,0,1]` plan
/// identically; `[n5,n9,n5] → [0,1,0]` does not collapse with them
/// (the TP subgroup is an allocation-order prefix, so order matters).
pub fn alloc_shape(alloc: &Allocation) -> Vec<u32> {
    let mut labels: Vec<(usize, u32)> = vec![]; // (node, label)
    let mut out = Vec::with_capacity(alloc.gpus.len());
    for g in &alloc.gpus {
        let label = match labels.iter().find(|(n, _)| *n == g.node) {
            Some(&(_, l)) => l,
            None => {
                let l = labels.len() as u32;
                labels.push((g.node, l));
                l
            }
        };
        out.push(label);
    }
    out
}

/// Ordered per-node run-length key of an allocation: `(node, count)`
/// for each maximal run of same-node GPUs in allocation order. Keeps
/// the physical node ids (unlike [`alloc_shape`]) but drops the local
/// GPU indices, which plans cannot depend on — the predictor's
/// *exact-level* cache keys use this.
pub fn alloc_node_runs(alloc: &Allocation) -> Vec<(usize, u32)> {
    let mut out: Vec<(usize, u32)> = vec![];
    for g in &alloc.gpus {
        if let Some(last) = out.last_mut() {
            if last.0 == g.node {
                last.1 += 1;
                continue;
            }
        }
        out.push((g.node, 1));
    }
    out
}

/// Plan under a forced (pp, tp) shape instead of searching. Used for
/// like-for-like comparisons where the shape search would otherwise
/// change underneath (e.g. the spread-placement tests comparing the
/// comm terms of identical shapes on packed vs cross-node allocations).
pub fn plan_with_shape(
    ssm: &Ssm,
    alloc: &Allocation,
    spec: &ClusterSpec,
    opts: &PlanOptions,
    pp: usize,
    tp: usize,
) -> Result<ParallelPlan, PlanError> {
    plan_fixed(ssm, alloc, spec, opts, pp, tp)
}

/// All (pp, tp) with pp*tp == n, pp bounded by the layer-chain length.
fn factorizations(n: usize, max_pp: usize) -> Vec<(usize, usize)> {
    let mut out = vec![];
    for pp in 1..=n {
        if n % pp == 0 && pp <= max_pp {
            let tp = n / pp;
            // tensor parallel beyond 8 ways is unrealistic for attention
            // heads; planners cap it at the node width
            if tp <= 8 {
                out.push((pp, tp));
            }
        }
    }
    out
}

/// GEMM efficiency saturates with per-microbatch token count: small
/// fused batches cannot fill the device (the §2 residual capacity that
/// makes co-location profitable). Michaelis–Menten with half-saturation
/// at `512 * sqrt(tp)` tokens — tensor parallelism narrows per-GPU GEMMs
/// (mild penalty) but the token rows still amortize the per-wave fixed
/// costs, which is precisely why fusing under-utilized jobs wins.
fn saturating_eff(mfu_cap: f64, tokens_per_microbatch: f64, tp: usize)
    -> f64 {
    let half = 1024.0 * (tp as f64).sqrt();
    mfu_cap * tokens_per_microbatch / (tokens_per_microbatch + half)
}

fn plan_fixed(
    ssm: &Ssm,
    alloc: &Allocation,
    spec: &ClusterSpec,
    opts: &PlanOptions,
    pp: usize,
    tp: usize,
) -> Result<ParallelPlan, PlanError> {
    let gpu = &spec.gpu;
    let ways = pp * tp;

    // ---- memory feasibility ----
    // the tightest GPU paces feasibility: every model-parallel shard
    // must fit on its device, and the smallest tier hosts one of them
    // (×1.0 — bit-exact — on homogeneous fleets)
    let min_mem_mult = alloc
        .gpus
        .iter()
        .map(|g| spec.tier_of(g.node).mem_mult)
        .fold(f64::INFINITY, f64::min);
    let jobs: Vec<(LoraSpec, usize, usize)> = ssm
        .adapters
        .iter()
        .map(|a| (LoraSpec::new(a.rank), a.batch_size, a.seq_len))
        .collect();
    let mem = memory_of(&ssm.arch, &jobs, ways).total();
    let have = gpu.mem_bytes * min_mem_mult;
    if mem > have {
        return Err(PlanError::OutOfMemory { need: mem, have });
    }

    // ---- microbatch count (needed for the efficiency model) ----
    // pp == 1 needs no splitting; pipelines fill with up to 4 in-flight
    // microbatches per stage
    let total_batch = ssm.total_batch().max(1);
    let m = if pp == 1 {
        1
    } else {
        total_batch.clamp(1, 4 * pp)
    };

    // ---- per-layer compute profile (full batch, divided over tp) ----
    let tokens_mb = ssm.total_tokens() / m as f64;
    let eff_flops =
        gpu.peak_flops * saturating_eff(gpu.mfu_cap, tokens_mb, tp);
    // per-microbatch per-layer kernel launches (fwd+bwd chain)
    let layer_fixed = m as f64 * 6.0 * gpu.launch_overhead_s;
    let adapter_loads: Vec<AdapterLoad> = ssm
        .adapters
        .iter()
        .map(|a| AdapterLoad {
            rank: a.rank,
            tokens: a.tokens(),
        })
        .collect();
    // adapter kernel time on one fused layer invocation (per GPU slice)
    let adapter_t = adapter_exec_time(
        gpu,
        ssm.arch.d_model,
        &adapter_loads,
        opts.fused_kernel,
    ) / tp as f64;

    let layer_flops = ssm.layer_flops();
    let n_chain = layer_flops.len();
    // index 0 (embed) and n-1 (head) carry no adapters
    let layer_times: Vec<f64> = layer_flops
        .iter()
        .enumerate()
        .map(|(i, &f)| {
            let backbone = f / (tp as f64 * eff_flops) + layer_fixed;
            if i == 0 || i == n_chain - 1 {
                backbone
            } else {
                backbone + adapter_t
            }
        })
        .collect();

    // ---- TP communication: 4 allreduces of the activation slice per
    // layer per step (2 fwd + 2 bwd), over the tp subgroup ----
    let tp_comm = if tp > 1 {
        let sub: Vec<_> = alloc.gpus.iter().take(tp).cloned().collect();
        let bytes = ssm.boundary_bytes();
        4.0 * (ssm.arch.n_layers as f64)
            * spec.allreduce_time(&sub, bytes)
    } else {
        0.0
    };

    // ---- pipeline partition (DP over contiguous stages) ----
    // stage s occupies the allocation-order GPU chunk
    // [s*tp, (s+1)*tp); a gang-synchronous stage runs at its slowest
    // member's generation, so the DP weighs each candidate segment by
    // the hosting stage's minimum compute multiplier (all 1.0 —
    // bit-exact — on homogeneous fleets). On mixed fleets this skews
    // layers toward fast stages, which is what lets pipeline splits
    // beat tensor parallelism (TP is paced by the slowest member of
    // the whole gang).
    debug_assert_eq!(alloc.n_gpus(), pp * tp);
    let stage_mults: Vec<f64> = (0..pp)
        .map(|s| {
            alloc.gpus[s * tp..(s + 1) * tp]
                .iter()
                .map(|g| spec.compute_mult(g.node))
                .fold(f64::INFINITY, f64::min)
        })
        .collect();
    let stages_cut = partition_dp_weighted(&layer_times, &stage_mults);
    let stages: Vec<Stage> = stages_cut
        .iter()
        .enumerate()
        .map(|(i, &(b, e))| Stage {
            begin: b,
            end: e,
            compute_s: layer_times[b..e].iter().sum::<f64>()
                / stage_mults[i],
        })
        .collect();
    let max_stage = stages
        .iter()
        .map(|s| s.compute_s)
        .fold(0.0f64, f64::max);

    // ---- p2p traffic across stage boundaries ----
    let p2p_comm = if pp > 1 {
        // boundary bytes cross each of the pp-1 cuts fwd + bwd
        let cut_bytes = ssm.boundary_bytes();
        let (a, b) = (alloc.gpus[0], alloc.gpus[alloc.n_gpus() - 1]);
        2.0 * (pp as f64 - 1.0) * spec.p2p_time(a, b, cut_bytes)
    } else {
        0.0
    };

    // ---- assemble compute & comm totals ----
    // fixed per-step costs: optimizer update + host sync
    let step_fixed = 5e-4;
    let comp: f64 =
        stages.iter().map(|s| s.compute_s).sum::<f64>() + step_fixed;
    let comm = tp_comm + p2p_comm;
    // 1F1B bubble: the pipeline multiplies the bottleneck stage
    let bubble_frac = if pp > 1 {
        (pp as f64 - 1.0) / (m as f64 + pp as f64 - 1.0)
    } else {
        0.0
    };
    // pipeline-extended compute: bottleneck stage repeated over the ramp
    let pipeline_comp =
        comp + (pp as f64 - 1.0) * (max_stage / m as f64);

    // ---- nano-batch overlap (Eq. 1) ----
    let oh = gpu.launch_overhead_s * 4.0; // per-nano relaunch of the chain
    let lat = if alloc.spans_nodes() {
        spec.ib_latency_s
    } else {
        1e-6
    };
    let (n_nano, step_time) = if opts.fused_kernel {
        match opts.n_nano {
            Some(n) => (
                n,
                overlap::iter_time(pipeline_comp, comm, n, oh, lat),
            ),
            None => {
                let cap = opts.n_nano_max.min(total_batch.max(1));
                overlap::best_fixed_n(pipeline_comp, comm, cap, oh, lat)
            }
        }
    } else {
        (1, overlap::serial_time(pipeline_comp, comm, oh, lat))
    };

    // ---- utilization ----
    let useful_flops: f64 = layer_flops.iter().sum::<f64>();
    // aggregate peak of the gang. Gated on uniformity: repeated
    // per-GPU addition is NOT bit-equal to `n as f64 *`, so the
    // homogeneous path must keep the original multiplication form
    let total_peak = if spec.is_uniform_reference() {
        alloc.n_gpus() as f64 * gpu.peak_flops
    } else {
        alloc
            .gpus
            .iter()
            .map(|g| gpu.peak_flops * spec.compute_mult(g.node))
            .sum::<f64>()
    };
    let compute_util = useful_flops / (total_peak * step_time);

    Ok(ParallelPlan {
        pp,
        tp,
        n_microbatches: m,
        stages,
        step_time_s: step_time,
        comp_s: pipeline_comp,
        comm_s: comm,
        bubble_frac,
        mem_per_gpu: mem,
        compute_util,
        n_nano,
    })
}

/// [`partition_dp`] with per-stage compute multipliers: segment
/// `[j, i)` assigned to stage `s` costs `seg(j, i) / mults[s]`, so the
/// DP minimizes the maximum *tier-scaled* stage time. With all-1.0
/// multipliers every cost is bit-identical to the unweighted DP
/// (`x / 1.0 == x` in IEEE bits) and the returned cuts match
/// [`partition_dp`] exactly — the homogeneous-fleet differential
/// depends on that.
fn partition_dp_weighted(
    times: &[f64],
    mults: &[f64],
) -> Vec<(usize, usize)> {
    let l = times.len();
    let k = mults.len().min(l).max(1);
    let mut pre = vec![0.0; l + 1];
    for i in 0..l {
        pre[i + 1] = pre[i] + times[i];
    }
    let seg = |a: usize, b: usize| pre[b] - pre[a]; // [a, b)

    let inf = f64::INFINITY;
    let mut dp = vec![vec![inf; l + 1]; k + 1];
    let mut cut = vec![vec![0usize; l + 1]; k + 1];
    dp[0][0] = 0.0;
    for s in 1..=k {
        let w = mults.get(s - 1).copied().unwrap_or(1.0);
        for i in s..=l {
            for j in (s - 1)..i {
                let cost = dp[s - 1][j].max(seg(j, i) / w);
                if cost < dp[s][i] {
                    dp[s][i] = cost;
                    cut[s][i] = j;
                }
            }
        }
    }
    let mut bounds = vec![];
    let mut i = l;
    for s in (1..=k).rev() {
        let j = cut[s][i];
        bounds.push((j, i));
        i = j;
    }
    bounds.reverse();
    bounds
}

/// Partition `times` into `k` contiguous stages minimizing the maximum
/// stage sum. Classic DP, O(L²·k). Returns [begin, end) ranges.
fn partition_dp(times: &[f64], k: usize) -> Vec<(usize, usize)> {
    let l = times.len();
    let k = k.min(l).max(1);
    // prefix sums
    let mut pre = vec![0.0; l + 1];
    for i in 0..l {
        pre[i + 1] = pre[i] + times[i];
    }
    let seg = |a: usize, b: usize| pre[b] - pre[a]; // [a, b)

    // dp[s][i] = min over cuts of max-stage cost for first i layers in s
    // stages; cut[s][i] = where stage s starts
    let inf = f64::INFINITY;
    let mut dp = vec![vec![inf; l + 1]; k + 1];
    let mut cut = vec![vec![0usize; l + 1]; k + 1];
    dp[0][0] = 0.0;
    for s in 1..=k {
        for i in s..=l {
            for j in (s - 1)..i {
                let cost = dp[s - 1][j].max(seg(j, i));
                if cost < dp[s][i] {
                    dp[s][i] = cost;
                    cut[s][i] = j;
                }
            }
        }
    }
    // backtrack
    let mut bounds = vec![];
    let mut i = l;
    for s in (1..=k).rev() {
        let j = cut[s][i];
        bounds.push((j, i));
        i = j;
    }
    bounds.reverse();
    bounds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Allocator, ClusterSpec};
    use crate::ssm::Ssm;
    use crate::workload::JobSpec;

    fn job(id: u64, rank: usize, batch: usize, seq: usize) -> JobSpec {
        JobSpec {
            id,
            base_model: "llama3-8b".into(),
            rank,
            batch_size: batch,
            seq_len: seq,
            gpus: 2,
            total_steps: 100,
            submit_time: 0.0,
            max_slowdown: 1.5,
        }
    }

    fn setup(n_gpus: usize) -> (ClusterSpec, Allocation) {
        let spec = ClusterSpec::default_128();
        let mut a = Allocator::new(spec.clone());
        let alloc = a.allocate(n_gpus).unwrap();
        (spec, alloc)
    }

    #[test]
    fn partition_dp_balances() {
        let times = vec![1.0, 1.0, 1.0, 1.0, 4.0, 1.0, 1.0, 1.0];
        let cuts = partition_dp(&times, 3);
        assert_eq!(cuts.len(), 3);
        assert_eq!(cuts[0].0, 0);
        assert_eq!(cuts.last().unwrap().1, times.len());
        // contiguous, non-overlapping
        for w in cuts.windows(2) {
            assert_eq!(w[0].1, w[1].0);
        }
        // bottleneck should be the 4.0 layer alone-ish
        let max: f64 = cuts
            .iter()
            .map(|&(a, b)| times[a..b].iter().sum::<f64>())
            .fold(0.0, f64::max);
        assert!(max <= 5.0, "{max}");
    }

    #[test]
    fn partition_dp_degenerate() {
        assert_eq!(partition_dp(&[1.0], 4), vec![(0, 1)]);
        assert_eq!(partition_dp(&[1.0, 2.0], 1), vec![(0, 2)]);
    }

    #[test]
    fn plan_single_gpu_single_job() {
        let (spec, alloc) = setup(1);
        let ssm = Ssm::fuse(&[job(0, 8, 4, 512)]).unwrap();
        let p = plan(&ssm, &alloc, &spec, &PlanOptions::default()).unwrap();
        assert_eq!(p.pp, 1);
        assert_eq!(p.tp, 1);
        assert!(p.step_time_s > 0.0);
        assert!(p.comm_s == 0.0);
        assert!(p.compute_util > 0.0 && p.compute_util <= 1.0);
    }

    #[test]
    fn plan_multi_gpu_reduces_step_time() {
        let ssm = Ssm::fuse(&[job(0, 8, 8, 1024), job(1, 8, 8, 1024)])
            .unwrap();
        let (spec, a1) = setup(1);
        let p1 = plan(&ssm, &a1, &spec, &PlanOptions::default()).unwrap();
        let (_, a4) = setup(4);
        let p4 = plan(&ssm, &a4, &spec, &PlanOptions::default()).unwrap();
        assert!(
            p4.step_time_s < p1.step_time_s,
            "{} vs {}",
            p4.step_time_s,
            p1.step_time_s
        );
    }

    #[test]
    fn plan_oom_for_tiny_gpu() {
        let mut spec = ClusterSpec::default_128();
        spec.gpu.mem_bytes = 1e9; // 1 GB cannot hold an 8B model
        let mut a = Allocator::new(spec.clone());
        let alloc = a.allocate(1).unwrap();
        let ssm = Ssm::fuse(&[job(0, 8, 4, 512)]).unwrap();
        assert!(matches!(
            plan(&ssm, &alloc, &spec, &PlanOptions::default()),
            Err(PlanError::OutOfMemory { .. })
        ));
    }

    #[test]
    fn fused_plan_no_slower_than_unfused() {
        let ssm = Ssm::fuse(&[
            job(0, 2, 1, 256),
            job(1, 4, 2, 256),
            job(2, 8, 1, 512),
            job(3, 16, 2, 512),
        ])
        .unwrap();
        let (spec, alloc) = setup(2);
        let fused = plan(&ssm, &alloc, &spec, &PlanOptions::default())
            .unwrap();
        let unfused = plan(
            &ssm,
            &alloc,
            &spec,
            &PlanOptions {
                fused_kernel: false,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(fused.step_time_s <= unfused.step_time_s);
    }

    #[test]
    fn bubble_fraction_formula() {
        let ssm = Ssm::fuse(&[job(0, 8, 8, 512)]).unwrap();
        let (spec, alloc) = setup(4);
        let p = plan(&ssm, &alloc, &spec, &PlanOptions::default()).unwrap();
        if p.pp > 1 {
            let expect = (p.pp as f64 - 1.0)
                / (p.n_microbatches as f64 + p.pp as f64 - 1.0);
            assert!((p.bubble_frac - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn stages_cover_chain_exactly() {
        let ssm = Ssm::fuse(&[job(0, 8, 4, 512)]).unwrap();
        let (spec, alloc) = setup(8);
        let p = plan(&ssm, &alloc, &spec, &PlanOptions::default()).unwrap();
        assert_eq!(p.stages.first().unwrap().begin, 0);
        assert_eq!(
            p.stages.last().unwrap().end,
            ssm.arch.n_layers + 2
        );
        for w in p.stages.windows(2) {
            assert_eq!(w[0].end, w[1].begin);
        }
    }

    #[test]
    fn alloc_shape_relabels_by_first_appearance() {
        use crate::cluster::GpuId;
        let a = Allocation {
            gpus: vec![
                GpuId { node: 5, idx: 3 },
                GpuId { node: 5, idx: 0 },
                GpuId { node: 9, idx: 1 },
            ],
        };
        let b = Allocation {
            gpus: vec![
                GpuId { node: 2, idx: 7 },
                GpuId { node: 2, idx: 4 },
                GpuId { node: 7, idx: 0 },
            ],
        };
        assert_eq!(alloc_shape(&a), vec![0, 0, 1]);
        assert_eq!(alloc_shape(&a), alloc_shape(&b));
        // interleaved order is a *different* pattern: the TP subgroup
        // is an allocation-order prefix
        let c = Allocation {
            gpus: vec![
                GpuId { node: 5, idx: 3 },
                GpuId { node: 9, idx: 1 },
                GpuId { node: 5, idx: 0 },
            ],
        };
        assert_eq!(alloc_shape(&c), vec![0, 1, 0]);
        assert_ne!(alloc_shape(&a), alloc_shape(&c));
    }

    #[test]
    fn alloc_node_runs_drop_idx_keep_order() {
        use crate::cluster::GpuId;
        let a = Allocation {
            gpus: vec![
                GpuId { node: 1, idx: 3 },
                GpuId { node: 1, idx: 0 },
                GpuId { node: 4, idx: 1 },
                GpuId { node: 1, idx: 7 },
            ],
        };
        assert_eq!(alloc_node_runs(&a), vec![(1, 2), (4, 1), (1, 1)]);
        // same nodes, different local indices: identical key
        let b = Allocation {
            gpus: vec![
                GpuId { node: 1, idx: 5 },
                GpuId { node: 1, idx: 6 },
                GpuId { node: 4, idx: 0 },
                GpuId { node: 1, idx: 2 },
            ],
        };
        assert_eq!(alloc_node_runs(&a), alloc_node_runs(&b));
    }

    #[test]
    fn same_shape_allocations_plan_bit_identically() {
        // the PlanShapeKey contract: equal keys ⇒ bit-identical plans.
        // Same per-node GPU pattern on different physical nodes (and
        // different local indices) must produce the same plan.
        use crate::cluster::GpuId;
        let spec = ClusterSpec::default_128();
        let ssm =
            Ssm::fuse(&[job(0, 8, 4, 512), job(1, 4, 2, 256)]).unwrap();
        let opts = PlanOptions::default();
        let a = Allocation {
            gpus: vec![
                GpuId { node: 0, idx: 0 },
                GpuId { node: 0, idx: 1 },
                GpuId { node: 1, idx: 0 },
                GpuId { node: 1, idx: 1 },
            ],
        };
        let b = Allocation {
            gpus: vec![
                GpuId { node: 7, idx: 5 },
                GpuId { node: 7, idx: 2 },
                GpuId { node: 3, idx: 6 },
                GpuId { node: 3, idx: 1 },
            ],
        };
        assert_eq!(
            PlanShapeKey::of(&ssm, &a, &spec, &opts),
            PlanShapeKey::of(&ssm, &b, &spec, &opts)
        );
        let pa = plan(&ssm, &a, &spec, &opts).unwrap();
        let pb = plan(&ssm, &b, &spec, &opts).unwrap();
        assert_eq!(pa.step_time_s.to_bits(), pb.step_time_s.to_bits());
        assert_eq!(pa.comm_s.to_bits(), pb.comm_s.to_bits());
        assert_eq!(pa.comp_s.to_bits(), pb.comp_s.to_bits());
        assert_eq!(pa, pb);
    }

    #[test]
    fn weighted_dp_with_unit_mults_matches_unweighted() {
        // x / 1.0 == x in IEEE bits, so the weighted DP must return
        // exactly the cuts of the classic DP — the homogeneous-fleet
        // byte-identity differential rests on this.
        let times = vec![1.0, 1.0, 1.0, 1.0, 4.0, 1.0, 1.0, 1.0];
        for k in 1..=4 {
            assert_eq!(
                partition_dp_weighted(&times, &vec![1.0; k]),
                partition_dp(&times, k),
                "k={k}"
            );
        }
        assert_eq!(
            partition_dp_weighted(&[1.0], &[1.0; 4]),
            partition_dp(&[1.0], 4)
        );
    }

    #[test]
    fn weighted_dp_skews_layers_toward_fast_stage() {
        // stage 0 twice as fast as stage 1: balancing seg/2.0 against
        // seg/1.0 must hand the fast stage the larger layer share
        let times = vec![1.0; 12];
        let cuts = partition_dp_weighted(&times, &[2.0, 1.0]);
        assert_eq!(cuts.len(), 2);
        let fast = cuts[0].1 - cuts[0].0;
        let slow = cuts[1].1 - cuts[1].0;
        assert!(fast > slow, "fast={fast} slow={slow}");
        assert_eq!(fast + slow, times.len());
    }

    #[test]
    fn homogeneous_spec_keys_have_empty_tier_components() {
        let (spec, alloc) = setup(4);
        let ssm = Ssm::fuse(&[job(0, 8, 4, 512)]).unwrap();
        let key =
            PlanShapeKey::of(&ssm, &alloc, &spec, &PlanOptions::default());
        assert!(key.tier_shape.is_empty());
        assert!(key.tier_table.is_empty());
    }

    #[test]
    fn distinct_tier_patterns_give_distinct_keys_and_plans() {
        use crate::cluster::GpuId;
        let homo = ClusterSpec::default_128();
        let mut mixed = ClusterSpec::default_128();
        mixed.apply_hardware_mix("a100:v100").unwrap();
        let ssm =
            Ssm::fuse(&[job(0, 8, 4, 512), job(1, 4, 2, 256)]).unwrap();
        let opts = PlanOptions::default();
        // nodes 0 (a100) and 1 (v100) under the alternating mix
        let a = Allocation {
            gpus: vec![
                GpuId { node: 0, idx: 0 },
                GpuId { node: 0, idx: 1 },
                GpuId { node: 1, idx: 0 },
                GpuId { node: 1, idx: 1 },
            ],
        };
        let k_homo = PlanShapeKey::of(&ssm, &a, &homo, &opts);
        let k_mixed = PlanShapeKey::of(&ssm, &a, &mixed, &opts);
        assert_ne!(k_homo, k_mixed);
        assert!(!k_mixed.tier_shape.is_empty());
        assert_eq!(k_mixed.tier_table.len(), 2);
        // and the plans genuinely differ: the v100 half slows the gang
        let p_homo = plan(&ssm, &a, &homo, &opts).unwrap();
        let p_mixed = plan(&ssm, &a, &mixed, &opts).unwrap();
        assert!(
            p_mixed.step_time_s > p_homo.step_time_s,
            "{} vs {}",
            p_mixed.step_time_s,
            p_homo.step_time_s
        );
        // same labels, opposite tier order: the multiplier bit-pattern
        // table must keep the keys apart (labels alone would collapse)
        let c = Allocation {
            gpus: vec![
                GpuId { node: 1, idx: 0 },
                GpuId { node: 1, idx: 1 },
                GpuId { node: 2, idx: 0 },
                GpuId { node: 2, idx: 1 },
            ],
        };
        let k_rev = PlanShapeKey::of(&ssm, &c, &mixed, &opts);
        assert_eq!(k_mixed.tier_shape, k_rev.tier_shape);
        assert_ne!(k_mixed, k_rev);
    }

    #[test]
    fn same_tier_pattern_on_other_nodes_keys_and_plans_equal() {
        use crate::cluster::GpuId;
        let mut spec = ClusterSpec::default_128();
        spec.apply_hardware_mix("a100:v100").unwrap();
        let ssm =
            Ssm::fuse(&[job(0, 8, 4, 512), job(1, 4, 2, 256)]).unwrap();
        let opts = PlanOptions::default();
        // nodes (0,1) and (2,3) carry the same (a100, v100) pattern
        let a = Allocation {
            gpus: vec![
                GpuId { node: 0, idx: 0 },
                GpuId { node: 0, idx: 1 },
                GpuId { node: 1, idx: 0 },
                GpuId { node: 1, idx: 1 },
            ],
        };
        let b = Allocation {
            gpus: vec![
                GpuId { node: 2, idx: 5 },
                GpuId { node: 2, idx: 2 },
                GpuId { node: 3, idx: 6 },
                GpuId { node: 3, idx: 1 },
            ],
        };
        assert_eq!(
            PlanShapeKey::of(&ssm, &a, &spec, &opts),
            PlanShapeKey::of(&ssm, &b, &spec, &opts)
        );
        let pa = plan(&ssm, &a, &spec, &opts).unwrap();
        let pb = plan(&ssm, &b, &spec, &opts).unwrap();
        assert_eq!(pa.step_time_s.to_bits(), pb.step_time_s.to_bits());
        assert_eq!(pa, pb);
    }

    #[test]
    fn flat_topology_keys_have_empty_topology_components() {
        // the byte-freedom contract at the cache-key level: a flat
        // tree adds nothing, so pre-topology keys (and cached plans)
        // are untouched
        let (spec, alloc) = setup(4);
        assert!(spec.topology.is_flat());
        let ssm = Ssm::fuse(&[job(0, 8, 4, 512)]).unwrap();
        let key = PlanShapeKey::of(
            &ssm,
            &alloc,
            &spec,
            &PlanOptions::default(),
        );
        assert!(key.rack_shape.is_empty());
        assert!(key.region_shape.is_empty());
        assert!(key.topo_table.is_empty());
    }

    #[test]
    fn hole_free_keys_have_empty_hole_component() {
        // the byte-freedom contract for single-GPU faults: no holes
        // on any touched node means no hole component, so pre-hole
        // keys (and cached plans) are untouched
        let (spec, alloc) = setup(4);
        let ssm = Ssm::fuse(&[job(0, 8, 4, 512)]).unwrap();
        let opts = PlanOptions::default();
        let plain = PlanShapeKey::of(&ssm, &alloc, &spec, &opts);
        assert!(plain.hole_shape.is_empty());
        // an explicit all-zero hole vector keys identically to `of`
        let zeros = vec![0u32; spec.n_nodes];
        assert_eq!(
            PlanShapeKey::of_with_holes(&ssm, &alloc, &spec, &zeros, &opts),
            plain
        );
        // holes on nodes the allocation never touches are invisible
        let mut elsewhere = vec![0u32; spec.n_nodes];
        elsewhere[spec.n_nodes - 1] = 3;
        assert_eq!(
            PlanShapeKey::of_with_holes(
                &ssm, &alloc, &spec, &elsewhere, &opts
            ),
            plain
        );
    }

    #[test]
    fn hole_patterns_key_apart_by_surviving_count() {
        let (spec, alloc) = setup(4); // best-fit: all on node 0
        let ssm = Ssm::fuse(&[job(0, 8, 4, 512)]).unwrap();
        let opts = PlanOptions::default();
        let with = |h0: u32| {
            let mut holes = vec![0u32; spec.n_nodes];
            holes[0] = h0;
            PlanShapeKey::of_with_holes(&ssm, &alloc, &spec, &holes, &opts)
        };
        let plain = PlanShapeKey::of(&ssm, &alloc, &spec, &opts);
        let one = with(1);
        let two = with(2);
        // a holed node keys apart from its hole-free shape, and the
        // surviving count (not just hole presence) is what's carried
        assert_ne!(one, plain);
        assert_ne!(two, plain);
        assert_ne!(one, two);
        assert_eq!(one.hole_shape, vec![7u32; 4]);
        assert_eq!(two.hole_shape, vec![6u32; 4]);
        // and the same hole depth keys identically (pure function)
        assert_eq!(one, with(1));
    }

    #[test]
    fn rack_patterns_key_apart_and_relabel_together() {
        use crate::cluster::GpuId;
        let mut spec = ClusterSpec::default_128(); // 16 nodes
        spec.apply_topology("racks=4:rack_bw=0.25").unwrap();
        let ssm =
            Ssm::fuse(&[job(0, 8, 4, 512), job(1, 4, 2, 256)]).unwrap();
        let opts = PlanOptions::default();
        let pair = |n1: usize, n2: usize| Allocation {
            gpus: vec![
                GpuId { node: n1, idx: 0 },
                GpuId { node: n2, idx: 0 },
            ],
        };
        // nodes 0,1 share rack 0; nodes 0,4 sit in racks 0 and 1 —
        // the node-equality pattern is identical, so only the rack
        // components can keep these apart (and must: cross-rack links
        // run at rack_bw)
        let same_rack = pair(0, 1);
        let cross_rack = pair(0, 4);
        let k_same = PlanShapeKey::of(&ssm, &same_rack, &spec, &opts);
        let k_cross =
            PlanShapeKey::of(&ssm, &cross_rack, &spec, &opts);
        assert_eq!(alloc_shape(&same_rack), alloc_shape(&cross_rack));
        assert_ne!(k_same, k_cross);
        // like-for-like shape: tp=2 allreduce over a 0.25x rack link
        // is strictly more expensive than over in-rack IB
        let p_same =
            plan_with_shape(&ssm, &same_rack, &spec, &opts, 1, 2)
                .unwrap();
        let p_cross =
            plan_with_shape(&ssm, &cross_rack, &spec, &opts, 1, 2)
                .unwrap();
        assert!(
            p_cross.comm_s > p_same.comm_s,
            "cross-rack comm {} <= same-rack {}",
            p_cross.comm_s,
            p_same.comm_s
        );
        // physical rack ids relabel away: racks (1,2) pattern-match
        // racks (0,1) and must share the key and the plan bits
        let other_racks = pair(4, 8);
        let k_other =
            PlanShapeKey::of(&ssm, &other_racks, &spec, &opts);
        assert_eq!(k_cross, k_other);
        let p_cross_best =
            plan(&ssm, &cross_rack, &spec, &opts).unwrap();
        let p_other = plan(&ssm, &other_racks, &spec, &opts).unwrap();
        assert_eq!(
            p_cross_best.step_time_s.to_bits(),
            p_other.step_time_s.to_bits()
        );
    }

    #[test]
    fn single_tier_gang_strictly_beats_tier_split_gang() {
        // the modeled half of the placement bugfix: on the pinned
        // mixed fleet (h100*3:v100, 4 nodes x 4 GPUs), the 8-GPU plan
        // on pure-h100 nodes is strictly faster than the plan on the
        // h100+v100 split the count-based allocator used to pick —
        // gang-synchronous pacing runs the split at v100 speed
        use crate::cluster::GpuId;
        let mut spec = ClusterSpec::with_gpus(16);
        spec.apply_hardware_mix("h100*3:v100").unwrap();
        let ssm =
            Ssm::fuse(&[job(0, 8, 4, 512), job(1, 4, 4, 512)]).unwrap();
        let opts = PlanOptions::default();
        let gang = |n1: usize, n2: usize| Allocation {
            gpus: (0..8)
                .map(|i| GpuId {
                    node: if i < 4 { n1 } else { n2 },
                    idx: i % 4,
                })
                .collect(),
        };
        let pure = gang(0, 1); // both h100
        let split = gang(0, 3); // h100 + v100 (the old pick)
        let p_pure = plan(&ssm, &pure, &spec, &opts).unwrap();
        let p_split = plan(&ssm, &split, &spec, &opts).unwrap();
        assert!(
            p_pure.step_time_s < p_split.step_time_s,
            "single-tier step {} not below split step {}",
            p_pure.step_time_s,
            p_split.step_time_s
        );
    }

    #[test]
    fn pipeline_split_beats_tp_on_strongly_mixed_pair() {
        // a 10x-slower second GPU paces the whole gang under tp=2, but a
        // pipeline split hands the slow stage a sliver of layers — the
        // cost search must pick pp=2 (the acceptance criterion's
        // "pipeline plans selected where cost-optimal")
        use crate::cluster::{GpuId, HardwareTier};
        let mut spec = ClusterSpec::default_128();
        spec.tiers.push(HardwareTier {
            name: "slow10".into(),
            compute_mult: 0.1,
            bw_mult: 1.0,
            mem_mult: 1.0,
        });
        spec.node_tier = vec![0, 1]; // odd nodes 10x slower
        spec.validate().unwrap();
        let ssm = Ssm::fuse(&[job(0, 8, 8, 512)]).unwrap();
        let opts = PlanOptions::default();
        let alloc = Allocation {
            gpus: vec![
                GpuId { node: 0, idx: 0 },
                GpuId { node: 1, idx: 0 },
            ],
        };
        let best = plan(&ssm, &alloc, &spec, &opts).unwrap();
        let forced_tp =
            plan_with_shape(&ssm, &alloc, &spec, &opts, 1, 2).unwrap();
        assert_eq!(best.pp, 2, "best shape {:?}", (best.pp, best.tp));
        assert!(
            best.step_time_s < forced_tp.step_time_s,
            "{} vs {}",
            best.step_time_s,
            forced_tp.step_time_s
        );
        // the fast stage (allocation prefix, node 0) carries more layers
        assert_eq!(best.stages.len(), 2);
        let fast = best.stages[0].end - best.stages[0].begin;
        let slow = best.stages[1].end - best.stages[1].begin;
        assert!(fast > slow, "fast={fast} slow={slow}");
    }

    #[test]
    fn explicit_nano_count_respected() {
        let ssm = Ssm::fuse(&[job(0, 8, 8, 512)]).unwrap();
        let (spec, alloc) = setup(2);
        let p = plan(
            &ssm,
            &alloc,
            &spec,
            &PlanOptions {
                n_nano: Some(4),
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(p.n_nano, 4);
    }
}
