//! Real end-to-end training: synthetic corpus generation, the training
//! driver over the PJRT runtime, and the micro-benchmark pass that
//! calibrates the simulator (§4.1's "micro-benchmarks on real hardware"
//! methodology; Fig. 10 checks the extrapolation accuracy).

pub mod data;
pub mod driver;
pub mod microbench;

pub use data::SyntheticCorpus;
pub use driver::{TrainReport, train_variant};
pub use microbench::{calibrate, MicrobenchResult};
