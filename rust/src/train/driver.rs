//! End-to-end training driver: runs a fused SSM variant for N steps on
//! the synthetic corpus, logging per-job loss curves.

use std::path::Path;

use anyhow::Result;

use super::data::SyntheticCorpus;
use crate::runtime::{Runtime, Trainer};

/// Loss trajectory of one training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub variant: String,
    pub steps: u64,
    /// (step, fused loss) — sampled every `log_every`
    pub loss_curve: Vec<(u64, f32)>,
    /// (step, per-adapter losses)
    pub per_adapter_curve: Vec<(u64, Vec<f32>)>,
    pub first_loss: f32,
    pub last_loss: f32,
    pub mean_step_s: f64,
    pub tokens_per_s: f64,
}

impl TrainReport {
    pub fn converged(&self) -> bool {
        self.last_loss < self.first_loss
    }

    pub fn render(&self) -> String {
        let mut s = format!(
            "variant={} steps={} first_loss={:.4} last_loss={:.4} \
             step={:.1} ms tokens/s={:.0}\n",
            self.variant,
            self.steps,
            self.first_loss,
            self.last_loss,
            self.mean_step_s * 1e3,
            self.tokens_per_s
        );
        for (step, loss) in &self.loss_curve {
            s.push_str(&format!("step {step:>6}  loss {loss:.4}\n"));
        }
        s
    }
}

/// Train `variant` for `steps` fused steps; `log_every` controls curve
/// resolution.
pub fn train_variant(
    artifacts_dir: &Path,
    variant: &str,
    steps: u64,
    seed: u64,
    log_every: u64,
) -> Result<TrainReport> {
    let runtime = Runtime::new(artifacts_dir)?;
    let mut trainer = Trainer::new(&runtime, variant, seed as i32)?;
    let cfg = trainer.variant().config.clone();
    let mut corpus = SyntheticCorpus::new(
        cfg.vocab,
        cfg.seq_len,
        cfg.num_adapters,
        seed ^ 0xDA7A,
    );

    let mut loss_curve = vec![];
    let mut per_adapter_curve = vec![];
    let mut first_loss = f32::NAN;
    let mut last_loss = f32::NAN;
    let tokens_per_step = (cfg.total_batch() * cfg.seq_len) as f64;

    let t0 = std::time::Instant::now();
    for step in 0..steps {
        let (tokens, ids) = corpus.fused_batch(&cfg.batch_sizes);
        let stats = trainer.step(&tokens, &ids)?;
        if step == 0 {
            first_loss = stats.loss;
        }
        last_loss = stats.loss;
        if step % log_every.max(1) == 0 || step + 1 == steps {
            loss_curve.push((step, stats.loss));
            per_adapter_curve.push((step, stats.per_adapter_loss.clone()));
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let mean_step_s = elapsed / steps.max(1) as f64;

    Ok(TrainReport {
        variant: variant.to_string(),
        steps,
        loss_curve,
        per_adapter_curve,
        first_loss,
        last_loss,
        mean_step_s,
        tokens_per_s: tokens_per_step / mean_step_s,
    })
}
