//! Synthetic training corpus (GSM8K stand-in, see DESIGN.md §3.2).
//!
//! Each adapter (job) gets its own learnable token process so per-job
//! loss curves separate: a periodic additive walk over the vocabulary
//! with job-specific stride and noise, plus a Zipf-distributed "content"
//! component. A small transformer learns these quickly, which is what
//! the end-to-end example needs to demonstrate real convergence.

use crate::util::rng::Rng;

/// Deterministic per-adapter sequence generator.
#[derive(Debug)]
pub struct SyntheticCorpus {
    vocab: usize,
    seq_len: usize,
    rng: Rng,
    /// per-adapter stride of the additive walk
    strides: Vec<usize>,
    /// per-adapter noise probability
    noise: Vec<f64>,
}

impl SyntheticCorpus {
    pub fn new(vocab: usize, seq_len: usize, num_adapters: usize,
               seed: u64) -> SyntheticCorpus {
        let mut rng = Rng::new(seed);
        let strides = (0..num_adapters)
            .map(|k| 1 + (k * 7 + rng.below(5)) % (vocab / 2).max(1))
            .collect();
        let noise = (0..num_adapters)
            .map(|_| rng.range_f64(0.02, 0.10))
            .collect();
        SyntheticCorpus {
            vocab,
            seq_len,
            rng,
            strides,
            noise,
        }
    }

    /// One sequence for adapter `k`.
    pub fn sequence(&mut self, k: usize) -> Vec<i32> {
        let stride = self.strides[k % self.strides.len()];
        let noise = self.noise[k % self.noise.len()];
        let mut tok = self.rng.below(self.vocab);
        let mut out = Vec::with_capacity(self.seq_len);
        for _ in 0..self.seq_len {
            out.push(tok as i32);
            if self.rng.bool(noise) {
                // content token from a Zipf tail
                tok = self.rng.zipf(self.vocab, 1.2);
            } else {
                tok = (tok + stride) % self.vocab;
            }
        }
        out
    }

    /// A fused batch: `batch_sizes[k]` sequences per adapter, laid out
    /// round-robin across adapters (the nano-batch-friendly layout —
    /// see `NanoLayout::round_robin`). Returns (tokens, adapter_ids).
    pub fn fused_batch(&mut self, batch_sizes: &[usize])
        -> (Vec<i32>, Vec<i32>) {
        let mut order: Vec<usize> = vec![];
        let mut remaining = batch_sizes.to_vec();
        loop {
            let mut any = false;
            for (k, r) in remaining.iter_mut().enumerate() {
                if *r > 0 {
                    order.push(k);
                    *r -= 1;
                    any = true;
                }
            }
            if !any {
                break;
            }
        }
        let mut tokens = Vec::with_capacity(order.len() * self.seq_len);
        let mut ids = Vec::with_capacity(order.len());
        for &k in &order {
            tokens.extend(self.sequence(k));
            ids.push(k as i32);
        }
        (tokens, ids)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SyntheticCorpus::new(256, 32, 4, 1);
        let mut b = SyntheticCorpus::new(256, 32, 4, 1);
        assert_eq!(a.sequence(0), b.sequence(0));
        assert_eq!(a.fused_batch(&[1, 2]), b.fused_batch(&[1, 2]));
    }

    #[test]
    fn tokens_in_vocab() {
        let mut c = SyntheticCorpus::new(100, 64, 2, 3);
        for k in 0..2 {
            for t in c.sequence(k) {
                assert!((0..100).contains(&t));
            }
        }
    }

    #[test]
    fn fused_batch_shapes_and_roundrobin() {
        let mut c = SyntheticCorpus::new(256, 16, 3, 5);
        let (tokens, ids) = c.fused_batch(&[1, 2, 3]);
        assert_eq!(ids.len(), 6);
        assert_eq!(tokens.len(), 6 * 16);
        // round-robin prefix: all three adapters appear before repeats
        assert_eq!(&ids[..3], &[0, 1, 2]);
        // counts match batch_sizes
        for k in 0..3 {
            assert_eq!(
                ids.iter().filter(|&&i| i == k as i32).count(),
                (k + 1) as usize
            );
        }
    }

    #[test]
    fn sequences_are_mostly_predictable() {
        // the walk structure must dominate noise for learnability
        let mut c = SyntheticCorpus::new(256, 128, 1, 7);
        let s = c.sequence(0);
        let stride_hits = s
            .windows(2)
            .filter(|w| {
                (w[0] as usize + c.strides[0]) % 256 == w[1] as usize
            })
            .count();
        assert!(
            stride_hits as f64 / (s.len() - 1) as f64 > 0.8,
            "{stride_hits}"
        );
    }

    #[test]
    fn adapters_have_distinct_processes() {
        let mut c = SyntheticCorpus::new(256, 64, 4, 9);
        assert_ne!(c.sequence(0), c.sequence(1));
    }
}
