//! Micro-benchmark + simulator calibration (Fig. 10).
//!
//! Mirrors the paper's methodology: measure real per-step times for the
//! AOT'd variants on the PJRT backend, fit the effective FLOP rate from
//! the *smallest* variants, and extrapolate the larger ones analytically
//! (cost model × fitted rate — the "homogeneity of transformer layers"
//! extrapolation the Sailor simulator uses). The gap between predicted
//! and measured step time is the simulator's accuracy.

use std::path::Path;

use anyhow::Result;

use super::data::SyntheticCorpus;
use crate::runtime::{Runtime, Trainer};

/// One variant's measured vs predicted step time.
#[derive(Debug, Clone)]
pub struct MicrobenchResult {
    pub variant: String,
    pub flops_per_step: f64,
    pub measured_step_s: f64,
    /// extrapolated from the calibration variants' effective FLOP rate
    pub predicted_step_s: f64,
    /// |predicted - measured| / measured
    pub error: f64,
    /// used to fit the rate (excluded from the accuracy claim)
    pub is_calibration: bool,
}

/// Measure `variants` with `steps` timed steps each (after `warmup`),
/// fit on `calibrate_on`, and report per-variant accuracy.
pub fn calibrate(
    artifacts_dir: &Path,
    variants: &[&str],
    calibrate_on: &[&str],
    warmup: u64,
    steps: u64,
) -> Result<Vec<MicrobenchResult>> {
    let runtime = Runtime::new(artifacts_dir)?;
    let mut measured: Vec<(String, f64, f64)> = vec![];

    for name in variants {
        let mut trainer = Trainer::new(&runtime, name, 0)?;
        let cfg = trainer.variant().config.clone();
        let flops = trainer.variant().flops_per_step;
        let mut corpus = SyntheticCorpus::new(
            cfg.vocab,
            cfg.seq_len,
            cfg.num_adapters,
            7,
        );
        for _ in 0..warmup {
            let (tokens, ids) = corpus.fused_batch(&cfg.batch_sizes);
            trainer.step(&tokens, &ids)?;
        }
        let t0 = std::time::Instant::now();
        for _ in 0..steps.max(1) {
            let (tokens, ids) = corpus.fused_batch(&cfg.batch_sizes);
            trainer.step(&tokens, &ids)?;
        }
        let per_step = t0.elapsed().as_secs_f64() / steps.max(1) as f64;
        measured.push((name.to_string(), flops, per_step));
    }

    // affine cost model t = a + flops/rate fitted by least squares on
    // the calibration set — the intercept captures the per-step fixed
    // overhead (dispatch, small-kernel ramp) that a pure FLOP-rate
    // model mis-attributes across scales
    let cal: Vec<(f64, f64)> = measured
        .iter()
        .filter(|(n, _, _)| calibrate_on.contains(&n.as_str()))
        .map(|(_, f, t)| (*f, *t))
        .collect();
    let (a, b) = affine_fit(&cal);

    Ok(measured
        .into_iter()
        .map(|(variant, flops, t)| {
            let predicted = (a + b * flops).max(0.0);
            MicrobenchResult {
                is_calibration: calibrate_on
                    .contains(&variant.as_str()),
                error: (predicted - t).abs() / t,
                variant,
                flops_per_step: flops,
                measured_step_s: t,
                predicted_step_s: predicted,
            }
        })
        .collect())
}

/// Least-squares fit of `t = a + b * flops` (degenerates gracefully for
/// a single calibration point: pure rate, zero intercept).
fn affine_fit(points: &[(f64, f64)]) -> (f64, f64) {
    match points.len() {
        0 => (0.0, 1e-9),
        1 => (0.0, points[0].1 / points[0].0),
        _ => {
            let n = points.len() as f64;
            let sx: f64 = points.iter().map(|p| p.0).sum();
            let sy: f64 = points.iter().map(|p| p.1).sum();
            let sxx: f64 = points.iter().map(|p| p.0 * p.0).sum();
            let sxy: f64 = points.iter().map(|p| p.0 * p.1).sum();
            let denom = n * sxx - sx * sx;
            if denom.abs() < 1e-30 {
                return (0.0, sy / sx.max(1e-30));
            }
            let b = (n * sxy - sx * sy) / denom;
            let a = (sy - b * sx) / n;
            (a.max(0.0), b.max(0.0))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::affine_fit;

    #[test]
    fn affine_fit_recovers_line() {
        let pts = [(1e9, 0.011), (2e9, 0.021), (4e9, 0.041)];
        let (a, b) = affine_fit(&pts);
        assert!((a - 0.001).abs() < 1e-4, "{a}");
        assert!((b - 1e-11).abs() < 1e-13, "{b}");
    }

    #[test]
    fn affine_fit_degenerate() {
        let (a, b) = affine_fit(&[(2e9, 0.02)]);
        assert_eq!(a, 0.0);
        assert!((b - 1e-11).abs() < 1e-13);
        let (a0, _) = affine_fit(&[]);
        assert_eq!(a0, 0.0);
    }
}
