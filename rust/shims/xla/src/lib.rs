//! Offline stub of the `xla` PJRT bindings (xla_extension 0.5.1 API).
//!
//! The container has no PJRT / xla_extension shared library, so this
//! shim provides the exact type-and-method surface `tlora::runtime`
//! compiles against while reporting "backend unavailable" the moment a
//! client is created. Every caller in the tlora crate already treats the
//! runtime as optional — CLI subcommands surface the error, integration
//! tests and benches skip when `artifacts/manifest.json` is missing —
//! so the stub turns the real-hardware paths into clean no-ops instead
//! of link failures. Swapping the real bindings back in is a one-line
//! change in rust/Cargo.toml.

use std::fmt;

/// Error type mirroring `xla::Error`: printed with `{e:?}` by callers.
#[derive(Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    fn unavailable(what: &str) -> Error {
        Error {
            msg: format!(
                "{what}: PJRT backend unavailable (built against the \
                 offline xla stub; link xla_extension for real execution)"
            ),
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy + 'static {}

impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i8 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}
impl NativeType for u32 {}
impl NativeType for u64 {}

/// A host tensor. The stub tracks shape/element count only — no program
/// ever executes, so no payload is needed.
pub struct Literal {
    elems: usize,
    dims: Vec<i64>,
}

impl Literal {
    /// 1-D literal from a host slice.
    pub fn vec1<T: NativeType>(values: &[T]) -> Literal {
        Literal {
            elems: values.len(),
            dims: vec![values.len() as i64],
        }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(_value: T) -> Literal {
        Literal {
            elems: 1,
            dims: vec![],
        }
    }

    /// Reinterpret with new dimensions; element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n < 0 || n as usize != self.elems {
            return Err(Error {
                msg: format!(
                    "reshape: {} elements into shape {dims:?}",
                    self.elems
                ),
            });
        }
        Ok(Literal {
            elems: self.elems,
            dims: dims.to_vec(),
        })
    }

    /// Dimensions of the literal.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Number of elements.
    pub fn element_count(&self) -> usize {
        self.elems
    }

    /// Decompose a tuple literal (stub: nothing ever produces tuples).
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }

    /// Copy out as a host vector (stub: no payload exists).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

/// Parsed HLO module (from the AOT'd `*.hlo.txt` interchange files).
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        match std::fs::read_to_string(path) {
            Ok(text) => Ok(HloModuleProto { _text: text }),
            Err(e) => Err(Error {
                msg: format!("read {path}: {e}"),
            }),
        }
    }
}

/// An XLA computation wrapping a parsed module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client handle. [`PjRtClient::cpu`] always fails in the stub, so
/// no other method is ever reached at run time.
#[derive(Clone)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable("PjRtClient::buffer_from_host_literal"))
    }
}

/// Device-resident buffer.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with host literals; result indexed `[replica][output]`.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>>
    where
        L: std::borrow::Borrow<Literal>,
    {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }

    /// Execute with device buffers; result indexed `[replica][output]`.
    pub fn execute_b<B>(&self, _args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>>
    where
        B: std::borrow::Borrow<PjRtBuffer>,
    {
        Err(Error::unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e:?}").contains("unavailable"));
    }

    #[test]
    fn literal_shape_tracking() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert!(l.reshape(&[3, 2]).is_err());
        let s = Literal::scalar(7i32);
        assert_eq!(s.element_count(), 1);
        assert!(s.dims().is_empty());
    }

    #[test]
    fn data_paths_error_cleanly() {
        let l = Literal::vec1(&[1i32]);
        assert!(l.to_vec::<i32>().is_err());
        assert!(l.to_tuple().is_err());
    }
}
