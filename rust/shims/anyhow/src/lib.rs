//! Offline stand-in for the `anyhow` crate.
//!
//! The vendor set ships no third-party crates, so this shim provides the
//! exact surface the tlora crate uses: [`Error`], [`Result`], the
//! [`anyhow!`] / [`bail!`] macros, and the [`Context`] extension trait
//! for both `Result` and `Option`. Like the real crate, [`Error`]
//! deliberately does **not** implement `std::error::Error` so the
//! blanket `From<E: std::error::Error>` conversion stays coherent.

use std::fmt;

/// A dynamic error: a message chain plus the originating error, if any.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from a plain message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
            source: None,
        }
    }

    /// Wrap a standard error.
    pub fn new<E>(error: E) -> Error
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        Error {
            msg: error.to_string(),
            source: Some(Box::new(error)),
        }
    }

    /// Prepend a context message (what `Context::context` delegates to).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: format!("{context}: {}", self.msg),
            source: self.source,
        }
    }

    /// The wrapped source error, if this error wraps one.
    pub fn source(
        &self,
    ) -> Option<&(dyn std::error::Error + Send + Sync + 'static)> {
        self.source.as_deref()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{e}` and `{e:#}` both print the full context chain; the chain
        // is already flattened into `msg`.
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// `anyhow::Result<T>` — a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures of `Result` and emptiness of `Option`.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "boom")
    }

    #[test]
    fn from_std_error_and_display() {
        let e: Error = io_err().into();
        assert_eq!(e.to_string(), "boom");
        assert!(e.source().is_some());
    }

    #[test]
    fn context_chains_messages() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening file").unwrap_err();
        assert_eq!(e.to_string(), "opening file: boom");
        let n: Option<u32> = None;
        let e = n.with_context(|| format!("slot {}", 3)).unwrap_err();
        assert_eq!(e.to_string(), "slot 3");
    }

    #[test]
    fn macros_build_errors() {
        fn fails(x: usize) -> Result<usize> {
            if x > 2 {
                bail!("too big: {x}");
            }
            Err(anyhow!("value {x}"))
        }
        assert_eq!(fails(9).unwrap_err().to_string(), "too big: 9");
        assert_eq!(fails(1).unwrap_err().to_string(), "value 1");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }
}
