//! L1 kernel micro-benchmarks (the §Perf deliverable for the Pallas
//! kernel): real fused vs unfused wall-clock on PJRT across adapter
//! counts, plus the analytic VMEM-footprint / MXU-utilization estimates
//! for the chosen BlockSpec (interpret=True timings are CPU-numpy, so
//! TPU performance is *estimated* structurally — see DESIGN.md §Perf).

use tlora::kernelsim::tile::{adapter_exec_time, AdapterLoad};
use tlora::metrics::Table;

fn main() {
    tlora::bench_util::section("kernel_micro — fused LoRA kernel");

    // --- analytic TPU-side estimates (BlockSpec structure) ---
    let mut vmem = Table::new(
        "VMEM footprint estimate per fwd grid step (tile_t x d_model)",
        &["tile_t", "d=768", "d=4096 (8B)", "fits 16MB VMEM"],
    );
    for tile_t in [64usize, 128, 256, 512] {
        let f = |d: usize| vmem_bytes(tile_t, d, 16, d) as f64 / 1e6;
        let fits = vmem_bytes(tile_t, 4096, 16, 4096) < 16 * (1 << 20);
        vmem.row(&[
            tile_t.to_string(),
            format!("{:.2} MB", f(768)),
            format!("{:.2} MB", f(4096)),
            if fits { "yes".into() } else { "NO".into() },
        ]);
    }
    vmem.print();

    let mut mxu = Table::new(
        "MXU utilization estimate (rank-padding efficiency of the fused \
         masked-accumulate schedule)",
        &["group", "estimate"],
    );
    for (name, loads) in [
        ("4 adapters, uniform r=16", vec![(16usize, 1024.0f64); 4]),
        ("4 adapters, ranks 2/4/8/16", vec![
            (2, 1024.0),
            (4, 1024.0),
            (8, 1024.0),
            (16, 1024.0),
        ]),
        ("1 adapter, r=16", vec![(16, 4096.0)]),
    ] {
        let tokens: Vec<f64> = loads.iter().map(|&(_, t)| t).collect();
        let ranks: Vec<usize> = loads.iter().map(|&(r, _)| r).collect();
        let est = mxu_estimate(&tokens, &ranks, 16);
        mxu.row(&[name.to_string(), format!("{:.1}%", est * 100.0)]);
    }
    mxu.print();

    // --- analytic A100 model (drives the simulator) ---
    let gpu = tlora::cluster::GpuSpec::a100_80g();
    let mut model = Table::new(
        "analytic kernel model — one fused layer invocation (A100 model)",
        &["K", "fused (us)", "unfused (us)", "speedup"],
    );
    for k in [1usize, 2, 4, 8, 16, 32] {
        let loads: Vec<AdapterLoad> = (0..k)
            .map(|i| AdapterLoad {
                rank: [2, 4, 8, 16][i % 4],
                tokens: 512.0,
            })
            .collect();
        let f = adapter_exec_time(&gpu, 4096, &loads, true);
        let u = adapter_exec_time(&gpu, 4096, &loads, false);
        model.row(&[
            k.to_string(),
            format!("{:.1}", f * 1e6),
            format!("{:.1}", u * 1e6),
            format!("{:.2}x", u / f),
        ]);
    }
    model.print();

    // --- real PJRT wall-clock (kmicro artifacts) ---
    let dir = std::path::Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        if let Ok(rt) = tlora::runtime::Runtime::new(dir) {
            let mut real = Table::new(
                "real PJRT CPU wall-clock — kmicro programs (fwd+bwd, \
                 T=512, d=256, r_max=16)",
                &["K", "fused (ms)", "unfused (ms)", "speedup"],
            );
            for k in [1usize, 4, 16] {
                let f = time_kmicro(&rt, &format!("kmicro_fused_k{k}"));
                let u = time_kmicro(&rt, &format!("kmicro_unfused_k{k}"));
                if let (Some(f), Some(u)) = (f, u) {
                    real.row(&[
                        k.to_string(),
                        format!("{:.2}", f * 1e3),
                        format!("{:.2}", u * 1e3),
                        format!("{:.2}x", u / f),
                    ]);
                }
            }
            real.print();
        }
    } else {
        println!("(artifacts missing — analytic tables only)");
    }
}

fn vmem_bytes(tile_t: usize, d: usize, r: usize, o: usize) -> usize {
    // mirrors python fused_lora.vmem_footprint_bytes
    (tile_t * d + d * r + r * o + tile_t * r + tile_t * o) * 4
}

fn mxu_estimate(tokens: &[f64], ranks: &[usize], r_max: usize) -> f64 {
    let d = 4096.0;
    let o = 4096.0;
    let total: f64 = tokens.iter().sum();
    let useful: f64 = tokens
        .iter()
        .zip(ranks)
        .map(|(&t, &r)| t * (d * r as f64 + r as f64 * o))
        .sum();
    let padded =
        ranks.len() as f64 * total * (d * r_max as f64 + r_max as f64 * o);
    useful / padded
}

fn time_kmicro(rt: &tlora::runtime::Runtime, name: &str) -> Option<f64> {
    let meta = rt.manifest.kmicro_by_name(name)?.clone();
    let exe = rt
        .compile(&tlora::runtime::ProgramMeta {
            file: meta.file.clone(),
            inputs: meta.inputs.clone(),
            outputs: meta.outputs.clone(),
        })
        .ok()?;
    let mut rng = tlora::util::rng::Rng::new(3);
    let args: Vec<xla::Literal> = meta
        .inputs
        .iter()
        .map(|spec| {
            let n = spec.elements();
            if spec.dtype == "i32" {
                let vals: Vec<i32> = (0..n)
                    .map(|_| rng.below(meta.k.max(1)) as i32)
                    .collect();
                tlora::runtime::Runtime::literal_i32(&vals, &spec.shape)
                    .unwrap()
            } else {
                let vals: Vec<f32> =
                    (0..n).map(|_| rng.f64() as f32 - 0.5).collect();
                tlora::runtime::Runtime::literal_f32(&vals, &spec.shape)
                    .unwrap()
            }
        })
        .collect();
    for _ in 0..2 {
        exe.run_literals(&args).ok()?;
    }
    let iters = 5;
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        exe.run_literals(&args).ok()?;
    }
    Some(t0.elapsed().as_secs_f64() / iters as f64)
}
