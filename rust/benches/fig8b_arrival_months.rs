//! Figure 8b (+ Figure 11): impact of the arrival trace — months 1/2/3
//! of the ACMETrace-style workload (1×/2×/4× concurrency, increasingly
//! bursty). Paper: month 1 has shorter JCT (partners readily available,
//! low contention) but slightly lower cluster throughput; months 2–3
//! sustain near-peak throughput despite bursty queues; JCT curves
//! flatten as the cluster saturates (Fig. 11).

use tlora::config::ExperimentConfig;
use tlora::metrics::{cdf_block, write_report, Table};
use tlora::sim::simulate;
use tlora::util::stats::Cdf;
use tlora::workload::trace::TraceProfile;

fn main() {
    tlora::bench_util::section("Figure 8b / 11 — arrival months");
    let months = [
        ("month 1 (1x)", TraceProfile::month1()),
        ("month 2 (2x)", TraceProfile::month2()),
        ("month 3 (4x)", TraceProfile::month3()),
    ];

    let mut t = Table::new(
        "tLoRA under month traces (100 jobs, 128 GPUs)",
        &["trace", "thr (samples/s)", "mean JCT (s)", "p99 JCT (s)",
          "util"],
    );
    let mut results = vec![];
    for (name, profile) in months {
        let mut cfg = ExperimentConfig::default();
        cfg.n_jobs = 200;
        cfg.trace = profile;
        let r = simulate(&cfg);
        t.row(&[
            name.to_string(),
            format!("{:.2}", r.avg_throughput),
            format!("{:.0}", r.mean_jct),
            format!("{:.0}", r.p99_jct),
            format!("{:.1}%", r.avg_gpu_util * 100.0),
        ]);
        results.push((name, r));
    }
    t.print();

    let m1 = &results[0].1;
    let m3 = &results[2].1;
    let thr_stable = m3.avg_throughput >= m1.avg_throughput * 0.8;
    let jct_grows = m3.mean_jct >= m1.mean_jct;
    println!(
        "\npaper shape: near-peak throughput under 4x burstier arrivals \
         while JCT grows with queueing -> {}",
        if thr_stable && jct_grows { "REPRODUCED" } else { "PARTIAL" }
    );

    let mut blocks = String::new();
    for (name, r) in &results {
        blocks.push_str(&cdf_block(name, &Cdf::of(&r.jct_values(), 50)));
        blocks.push('\n');
    }
    if let Some(p) = write_report("fig11_jct_by_month.txt", &blocks) {
        println!("Fig 11 JCT CDFs -> {}", p.display());
    }
}
