//! Figure 10: simulator accuracy — predicted vs real iteration time.
//!
//! Mirrors the paper's Sailor-style validation: measure real PJRT step
//! times for the AOT'd variants, fit the effective FLOP rate on the
//! small ones (the "profile a layer, extrapolate by homogeneity"
//! methodology), and check the prediction error on the held-out larger
//! variants. Paper claims simulation error within ~3% on their testbed;
//! we report ours on the CPU backend.
//!
//! Requires `make artifacts`. `--full` adds the 100M-parameter variant.

use tlora::metrics::Table;
use tlora::train::calibrate;

fn main() {
    tlora::bench_util::section("Figure 10 — simulator accuracy");
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("artifacts/ missing — run `make artifacts` first");
        return;
    }
    let full = std::env::args().any(|a| a == "--full");
    let variants: Vec<&str> = if full {
        vec!["tiny", "small", "med", "e2e100m"]
    } else {
        vec!["tiny", "small", "med"]
    };
    let cal: Vec<&str> = if full {
        vec!["tiny", "small", "med"]
    } else {
        vec!["tiny", "small"]
    };
    let steps = if full { 3 } else { 5 };
    match calibrate(dir, &variants, &cal, 2, steps) {
        Ok(results) => {
            let mut t = Table::new(
                "measured vs extrapolated step time (affine FLOPs fit, \
                 PJRT CPU backend)",
                &["variant", "GFLOPs/step", "measured (ms)",
                  "predicted (ms)", "error", "role"],
            );
            let mut held_out_errs = vec![];
            for r in &results {
                t.row(&[
                    r.variant.clone(),
                    format!("{:.1}", r.flops_per_step / 1e9),
                    format!("{:.1}", r.measured_step_s * 1e3),
                    format!("{:.1}", r.predicted_step_s * 1e3),
                    format!("{:.1}%", r.error * 100.0),
                    if r.is_calibration {
                        "calibration".into()
                    } else {
                        "held-out".into()
                    },
                ]);
                if !r.is_calibration {
                    held_out_errs.push(r.error);
                }
            }
            t.print();
            let worst = held_out_errs
                .iter()
                .cloned()
                .fold(0.0f64, f64::max);
            println!(
                "\npaper: <=3% simulation error (per-layer profiling on \
                 the A100 testbed). held-out extrapolation error here: \
                 {:.1}% on the CPU backend -> {}",
                worst * 100.0,
                if worst < 0.35 {
                    "shape holds (extrapolation from micro-profiles \
                     predicts unseen scales)"
                } else {
                    "degraded — CPU cache effects break FLOP scaling; \
                     see EXPERIMENTS.md notes"
                }
            );
        }
        Err(e) => println!("calibration failed: {e:#}"),
    }
}
