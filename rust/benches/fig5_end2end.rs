//! Figures 5a + 5b (and the §4 headline row): end-to-end cluster
//! throughput and job-completion time under online arrivals, tLoRA vs
//! mLoRA vs Megatron vs the two ablations.
//!
//! Paper claims: +41% throughput vs mLoRA (1.2–1.8× across loads),
//! 2.3–5.4× mean JCT reduction, mLoRA sometimes *below* Megatron.
//!
//! Thin driver over the sweep engine: the five policies run as one
//! parallel grid (`tlora::sweep`), one worker per policy.
//!
//! `--full` runs the paper-scale workload (slower).

use tlora::cli::Args;
use tlora::config::Policy;
use tlora::metrics::{cdf_block, write_report, Table};
use tlora::sweep::{run_parallel, SweepGrid};
use tlora::util::stats::Cdf;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let refs: Vec<&str> = argv.iter().map(String::as_str).collect();
    let args = Args::parse_from(&refs).unwrap();
    let full = args.has("full");

    tlora::bench_util::section("Figure 5 — end-to-end performance");
    let mut grid = SweepGrid::default();
    grid.policies = Policy::all().to_vec();
    grid.n_jobs = vec![if full { 600 } else { 250 }];
    grid.seeds = vec![args.get_u64("seed", 42).unwrap_or(42)];
    let run = run_parallel(&grid).expect("sweep failed");

    let mut t = Table::new(
        &format!(
            "Fig 5a/5b — {} jobs, {} GPUs ({} sims in {:.2}s on {} \
             threads)",
            grid.n_jobs[0],
            grid.gpus[0],
            run.points.len(),
            run.wall_s,
            run.n_threads
        ),
        &["policy", "thr (samples/s)", "mean JCT (s)", "p99 JCT (s)",
          "util", "sim (s)"],
    );
    for p in &run.points {
        let r = &p.result;
        t.row(&[
            p.point.policy.name().to_string(),
            format!("{:.2}", r.avg_throughput),
            format!("{:.0}", r.mean_jct),
            format!("{:.0}", r.p99_jct),
            format!("{:.1}%", r.avg_gpu_util * 100.0),
            format!("{:.2}", p.wall_s),
        ]);
    }
    t.print();

    let find = |p: Policy| &run.expect_one(|q| q.policy == p).result;
    let tl = find(Policy::TLora);
    let ml = find(Policy::MLora);
    let mg = find(Policy::Megatron);

    let mut c = Table::new(
        "paper-vs-measured",
        &["claim", "paper", "measured", "shape holds"],
    );
    let thr_gain = tl.avg_throughput / ml.avg_throughput;
    tlora::metrics::compare_row(
        &mut c,
        "throughput vs mLoRA",
        "+41% (1.2-1.8x)",
        thr_gain,
        "x",
        thr_gain > 1.1,
    );
    let jct_gain = ml.mean_jct / tl.mean_jct;
    tlora::metrics::compare_row(
        &mut c,
        "mean JCT vs mLoRA",
        "2.3-5.4x better",
        jct_gain,
        "x",
        jct_gain > 1.5,
    );
    let jct_mega = mg.mean_jct / tl.mean_jct;
    tlora::metrics::compare_row(
        &mut c,
        "mean JCT vs Megatron",
        "improved",
        jct_mega,
        "x",
        jct_mega > 1.0,
    );
    tlora::metrics::compare_row(
        &mut c,
        "mLoRA can trail Megatron (thr)",
        "observed",
        ml.avg_throughput / mg.avg_throughput,
        "x",
        true, // informational: depends on load
    );
    c.print();

    // Fig 5b CDFs → out/fig5b_jct_cdf.txt
    let mut blocks = String::new();
    for p in &run.points {
        let cdf = Cdf::of(&p.result.jct_values(), 50);
        blocks.push_str(&cdf_block(p.point.policy.name(), &cdf));
        blocks.push('\n');
    }
    if let Some(path) = write_report("fig5b_jct_cdf.txt", &blocks) {
        println!("\nJCT CDF series -> {}", path.display());
    }
}
