//! Figures 5a + 5b (and the §4 headline row): end-to-end cluster
//! throughput and job-completion time under online arrivals, tLoRA vs
//! mLoRA vs Megatron vs the two ablations.
//!
//! Paper claims: +41% throughput vs mLoRA (1.2–1.8× across loads),
//! 2.3–5.4× mean JCT reduction, mLoRA sometimes *below* Megatron.
//!
//! `--full` runs the paper-scale workload (slower).

use tlora::cli::Args;
use tlora::config::{ExperimentConfig, Policy};
use tlora::metrics::{cdf_block, write_report, Table};
use tlora::sim::{simulate, SimResult};
use tlora::util::stats::Cdf;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let refs: Vec<&str> = argv.iter().map(String::as_str).collect();
    let args = Args::parse_from(&refs).unwrap();
    let full = args.has("full");

    tlora::bench_util::section("Figure 5 — end-to-end performance");
    let mut base = ExperimentConfig::default();
    base.n_jobs = if full { 600 } else { 250 };
    base.seed = args.get_u64("seed", 42).unwrap_or(42);

    let mut results: Vec<(Policy, SimResult, f64)> = vec![];
    for policy in Policy::all() {
        let mut cfg = base.clone();
        cfg.policy = policy;
        let (r, wall) =
            tlora::bench_util::time_once(|| simulate(&cfg));
        results.push((policy, r, wall));
    }

    let mut t = Table::new(
        &format!(
            "Fig 5a/5b — {} jobs, {} GPUs (sim wall-clock per run shown)",
            base.n_jobs,
            base.cluster.total_gpus()
        ),
        &["policy", "thr (samples/s)", "mean JCT (s)", "p99 JCT (s)",
          "util", "sim (s)"],
    );
    for (p, r, wall) in &results {
        t.row(&[
            p.name().to_string(),
            format!("{:.2}", r.avg_throughput),
            format!("{:.0}", r.mean_jct),
            format!("{:.0}", r.p99_jct),
            format!("{:.1}%", r.avg_gpu_util * 100.0),
            format!("{wall:.2}"),
        ]);
    }
    t.print();

    let find = |p: Policy| results.iter().find(|(q, _, _)| *q == p).unwrap();
    let (_, tl, _) = find(Policy::TLora);
    let (_, ml, _) = find(Policy::MLora);
    let (_, mg, _) = find(Policy::Megatron);

    let mut c = Table::new(
        "paper-vs-measured",
        &["claim", "paper", "measured", "shape holds"],
    );
    let thr_gain = tl.avg_throughput / ml.avg_throughput;
    tlora::metrics::compare_row(
        &mut c,
        "throughput vs mLoRA",
        "+41% (1.2-1.8x)",
        thr_gain,
        "x",
        thr_gain > 1.1,
    );
    let jct_gain = ml.mean_jct / tl.mean_jct;
    tlora::metrics::compare_row(
        &mut c,
        "mean JCT vs mLoRA",
        "2.3-5.4x better",
        jct_gain,
        "x",
        jct_gain > 1.5,
    );
    let jct_mega = mg.mean_jct / tl.mean_jct;
    tlora::metrics::compare_row(
        &mut c,
        "mean JCT vs Megatron",
        "improved",
        jct_mega,
        "x",
        jct_mega > 1.0,
    );
    tlora::metrics::compare_row(
        &mut c,
        "mLoRA can trail Megatron (thr)",
        "observed",
        ml.avg_throughput / mg.avg_throughput,
        "x",
        true, // informational: depends on load
    );
    c.print();

    // Fig 5b CDFs → out/fig5b_jct_cdf.txt
    let mut blocks = String::new();
    for (p, r, _) in &results {
        let cdf = Cdf::of(&r.jct_values(), 50);
        blocks.push_str(&cdf_block(p.name(), &cdf));
        blocks.push('\n');
    }
    if let Some(path) = write_report("fig5b_jct_cdf.txt", &blocks) {
        println!("\nJCT CDF series -> {}", path.display());
    }
}
