//! Figure 7: performance breakdown — what each tLoRA component
//! contributes. Replacing the fused heterogeneous LoRA kernel with the
//! per-adapter "PyTorch-native" path weakens co-location (kernel-launch
//! overhead + poor reuse); replacing the Adapter Scheduler with mLoRA's
//! FIFO packing loses the complementarity gains.
//!
//! Two levels: (a) trace-driven policy ablation on the simulator, and
//! (b) *real* fused vs unfused kernel wall-clock on the PJRT runtime
//! (the AOT'd kmicro programs), which grounds the simulator's kernel
//! model in measured numbers.

use tlora::config::{ExperimentConfig, Policy};
use tlora::metrics::Table;
use tlora::sim::simulate;

fn main() {
    tlora::bench_util::section("Figure 7 — component breakdown");
    let mut base = ExperimentConfig::default();
    base.n_jobs = 200;

    let mut t = Table::new(
        "Fig 7 — policy ablation (trace-driven)",
        &["configuration", "thr (samples/s)", "mean JCT (s)",
          "vs full tLoRA"],
    );
    let mut full_thr = 0.0;
    for policy in [
        Policy::TLora,
        Policy::TLoraNoKernel,
        Policy::TLoraNoSched,
        Policy::MLora,
    ] {
        let mut cfg = base.clone();
        cfg.policy = policy;
        let r = simulate(&cfg);
        if policy == Policy::TLora {
            full_thr = r.avg_throughput;
        }
        t.row(&[
            policy.name().to_string(),
            format!("{:.2}", r.avg_throughput),
            format!("{:.0}", r.mean_jct),
            format!("{:.2}x", r.avg_throughput / full_thr),
        ]);
    }
    t.print();

    // (b) real kernel micro: measured on PJRT if artifacts are present
    let dir = std::path::Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        real_kernel_micro(dir);
    } else {
        println!("\n(artifacts/ missing — skip real kernel micro; run \
                  `make artifacts`)");
    }
}

fn real_kernel_micro(dir: &std::path::Path) {
    use tlora::runtime::Runtime;
    let rt = match Runtime::new(dir) {
        Ok(r) => r,
        Err(e) => {
            println!("runtime unavailable: {e:#}");
            return;
        }
    };
    let mut t = Table::new(
        "Fig 7 (real numerics) — fused vs unfused LoRA kernel, PJRT CPU",
        &["K adapters", "fused (ms)", "unfused (ms)", "speedup"],
    );
    for k in [1usize, 4, 16] {
        let fused = time_kmicro(&rt, &format!("kmicro_fused_k{k}"));
        let unfused = time_kmicro(&rt, &format!("kmicro_unfused_k{k}"));
        if let (Some(f), Some(u)) = (fused, unfused) {
            t.row(&[
                k.to_string(),
                format!("{:.2}", f * 1e3),
                format!("{:.2}", u * 1e3),
                format!("{:.2}x", u / f),
            ]);
        }
    }
    t.print();
    println!(
        "paper shape: unfused fragments into per-adapter launches; the \
         gap widens with K"
    );
}

fn time_kmicro(rt: &tlora::runtime::Runtime, name: &str) -> Option<f64> {
    let meta = rt.manifest.kmicro_by_name(name)?.clone();
    let exe = rt
        .compile(&tlora::runtime::ProgramMeta {
            file: meta.file.clone(),
            inputs: meta.inputs.clone(),
            outputs: meta.outputs.clone(),
        })
        .ok()?;
    // build inputs from the manifest specs
    let mut rng = tlora::util::rng::Rng::new(7);
    let args: Vec<xla::Literal> = meta
        .inputs
        .iter()
        .map(|spec| {
            let n: usize = spec.elements();
            if spec.dtype == "i32" {
                let vals: Vec<i32> = (0..n)
                    .map(|_| rng.below(meta.k.max(1)) as i32)
                    .collect();
                tlora::runtime::Runtime::literal_i32(&vals, &spec.shape)
                    .unwrap()
            } else {
                let vals: Vec<f32> =
                    (0..n).map(|_| rng.f64() as f32 - 0.5).collect();
                tlora::runtime::Runtime::literal_f32(&vals, &spec.shape)
                    .unwrap()
            }
        })
        .collect();
    // warmup + timed runs
    for _ in 0..2 {
        exe.run_literals(&args).ok()?;
    }
    let iters = 5;
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        exe.run_literals(&args).ok()?;
    }
    Some(t0.elapsed().as_secs_f64() / iters as f64)
}
