//! Figure 2: naïve batch LoRA training may hurt aggregate throughput.
//!
//! Three Llama3-8B LoRA jobs batched *naïvely* (mLoRA-style: per-adapter
//! kernels, no nano-batch overlap, no placement awareness — exactly what
//! §2 critiques). Pairwise fused groups vs isolated runs:
//!
//! * jobs 1+3, co-located within a node → aggregate throughput improves
//!   (paper: 0.74 + 1.09 → 2.36);
//! * jobs 1+2, grouped across nodes → communication amplifies and the
//!   pair regresses below isolated execution (paper: "clear regressions
//!   … especially when jobs are grouped across nodes").

use tlora::config::{ExperimentConfig, Policy};
use tlora::metrics::Table;
use tlora::sim::static_group_throughput;
use tlora::workload::JobSpec;

fn job(id: u64, rank: usize, batch: usize, seq: usize) -> JobSpec {
    JobSpec {
        id,
        base_model: "llama3-8b".into(),
        rank,
        batch_size: batch,
        seq_len: seq,
        gpus: 1,
        total_steps: 1000,
        submit_time: 0.0,
        max_slowdown: 10.0, // Fig. 2 measures naive batching: no guard
    }
}

fn main() {
    tlora::bench_util::section("Figure 2 — naive batching effects");
    let mut cfg = ExperimentConfig::default();
    cfg.policy = Policy::MLora; // naive batching: unfused, serial comm

    // Job 1: light; Job 2: heavy/saturated; Job 3: medium.
    let j1 = job(1, 4, 2, 512);
    let j2 = job(2, 16, 8, 1024);
    let j3 = job(3, 8, 4, 512);

    let iso = |j: &JobSpec| {
        static_group_throughput(&cfg, std::slice::from_ref(j), 1, false)
            .unwrap()
    };
    let (t1, t2, t3) = (iso(&j1), iso(&j2), iso(&j3));

    let pair = |a: &JobSpec, b: &JobSpec, spread: bool| {
        static_group_throughput(&cfg, &[a.clone(), b.clone()], 2, spread)
            .unwrap()
    };
    let t13 = pair(&j1, &j3, false); // co-located within a node
    let t12 = pair(&j1, &j2, true); // grouped across nodes
    let t23 = pair(&j2, &j3, true); // two heavy jobs across nodes

    let mut t = Table::new(
        "aggregate throughput (samples/s), isolated vs naively batched",
        &["grouping", "placement", "isolated sum", "batched", "effect"],
    );
    let eff = |iso: f64, fused: f64| {
        format!(
            "{}{:.1}%",
            if fused >= iso { "+" } else { "" },
            (fused / iso - 1.0) * 100.0
        )
    };
    t.row(&[
        "jobs 1+3 (complementary)".into(),
        "intra-node".into(),
        format!("{:.2} ({:.2}+{:.2})", t1 + t3, t1, t3),
        format!("{t13:.2}"),
        eff(t1 + t3, t13),
    ]);
    t.row(&[
        "jobs 1+2 (light + saturated)".into(),
        "cross-node".into(),
        format!("{:.2} ({:.2}+{:.2})", t1 + t2, t1, t2),
        format!("{t12:.2}"),
        eff(t1 + t2, t12),
    ]);
    t.row(&[
        "jobs 2+3 (both heavy)".into(),
        "cross-node".into(),
        format!("{:.2} ({:.2}+{:.2})", t2 + t3, t2, t3),
        format!("{t23:.2}"),
        eff(t2 + t3, t23),
    ]);
    t.print();

    let good = t13 > (t1 + t3) * 1.02;
    let bad = t12 < (t1 + t2) * 0.98;
    println!(
        "\npaper shape: some groupings help, others regress -> {}",
        if good && bad { "REPRODUCED" } else { "NOT reproduced" }
    );
    println!(
        "(paper: J1+J3: 0.74+1.09 -> 2.36 improved; J1+J2 regressed; \
         tLoRA's scheduler exists to find the first kind and avoid the \
         second)"
    );
}
