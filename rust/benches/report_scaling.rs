//! Report-path scaling (§Perf deliverable: million-arrival sweeps in
//! O(1) report memory), behind the `report-scale` CI job:
//!
//! 1. **Streaming vs legacy differential** — the streaming report
//!    ([`StreamReport`]) must emit byte-identical canonical JSON and
//!    CSV to the legacy collect-then-emit path on a real multi-cell
//!    grid, at threads 1 and 4. Divergences are localized with the
//!    lazy byte-range differ (`json::diff`) so a failure names the
//!    first diverging path, not just "bytes differ".
//! 2. **O(1) allocation gate** — a counting global allocator measures
//!    the report path's peak live-byte growth while feeding P ∈
//!    {16, 64, 256} point results through file-backed sinks
//!    ([`Spool::file`]). Peak growth at P=256 must stay within 1.5×
//!    (+64 KiB slack) of P=16: the streaming path holds one cell
//!    accumulator and per-point scratch, never the result tree. The
//!    legacy tree path's peak is recorded alongside, informationally.
//! 3. **Arrival-scale smoke** — the hyperscale diurnal/tenant-mix
//!    generator produces `BENCH_REPORT_ARRIVALS` jobs (default 100k;
//!    `BENCH_REPORT_FULL=1` raises the default to 1M) with monotone
//!    ids, bounded submit-time jitter, and visible day/night density
//!    modulation; a modest diurnal simulation with a [`LoadObserver`]
//!    attached must leave canonical results untouched (the observer
//!    is passive) while binning the load profile.
//!
//! Results land in `BENCH_report.json` (override: `BENCH_REPORT_OUT`);
//! any check failure exits nonzero, so the CI job is a real gate.

use std::alloc::{GlobalAlloc, Layout, System};
use std::io::Write;
use std::sync::atomic::{AtomicUsize, Ordering::Relaxed};

use tlora::bench_util::{section, time_once};
use tlora::config::{ExperimentConfig, Policy};
use tlora::metrics::Table;
use tlora::sim::{simulate_jobs_with, EngineOptions, LoadObserver};
use tlora::sweep::{
    run_streaming, to_csv, to_json_canonical, PointResult, Spool,
    StreamReport, SweepGrid, SweepRun,
};
use tlora::util::json::{self, Json};
use tlora::workload::trace::{
    DiurnalProfile, TraceGenerator, TraceProfile,
};

// ---- counting allocator -------------------------------------------------

/// Thin wrapper over the system allocator tracking live bytes, the
/// high-water mark, and total allocation count. The bench resets the
/// peak to the current live size before each measured region, so
/// `PEAK - live_at_reset` is the region's peak memory *growth*.
struct CountingAlloc;

static LIVE: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);
static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            let live =
                LIVE.fetch_add(layout.size(), Relaxed) + layout.size();
            PEAK.fetch_max(live, Relaxed);
            ALLOCS.fetch_add(1, Relaxed);
        }
        p
    }

    unsafe fn dealloc(&self, p: *mut u8, layout: Layout) {
        System.dealloc(p, layout);
        LIVE.fetch_sub(layout.size(), Relaxed);
    }

    unsafe fn realloc(
        &self,
        p: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        let q = System.realloc(p, layout, new_size);
        if !q.is_null() {
            if new_size >= layout.size() {
                let grown = new_size - layout.size();
                let live = LIVE.fetch_add(grown, Relaxed) + grown;
                PEAK.fetch_max(live, Relaxed);
            } else {
                LIVE.fetch_sub(layout.size() - new_size, Relaxed);
            }
            ALLOCS.fetch_add(1, Relaxed);
        }
        q
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Collapse the high-water mark down to the current live size and
/// return that live size; subsequent `PEAK - returned` is the peak
/// growth of the region that follows.
fn reset_peak() -> usize {
    let live = LIVE.load(Relaxed);
    PEAK.store(live, Relaxed);
    live
}

// ---- section 1: streaming vs legacy differential ------------------------

fn differential_grid() -> SweepGrid {
    let mut g = SweepGrid::default();
    g.policies = vec![Policy::TLora, Policy::Megatron];
    g.n_jobs = vec![12];
    g.gpus = vec![32];
    g.rate_scales = vec![2.0];
    g.months = vec![1];
    g.mtbfs = vec![0.0, 900.0];
    g.seeds = vec![7, 8];
    g
}

/// Run the streaming report over `grid` with in-memory sinks and
/// return (canonical JSON, CSV).
fn stream_outputs(grid: &SweepGrid, threads: usize) -> (String, String) {
    let mut jbuf: Vec<u8> = Vec::new();
    let mut cbuf: Vec<u8> = Vec::new();
    let mut report = StreamReport::new(grid, false)
        .with_json(&mut jbuf, Spool::memory())
        .with_csv(&mut cbuf);
    let stats = run_streaming(grid, threads, &mut |pr| {
        report.point(&pr).map_err(|e| format!("report emission: {e}"))
    })
    .expect("differential grid sweep failed");
    report
        .finish(stats.n_threads, stats.wall_s)
        .expect("stream finish failed");
    (
        String::from_utf8(jbuf).expect("canonical JSON is UTF-8"),
        String::from_utf8(cbuf).expect("CSV is UTF-8"),
    )
}

/// Compare two canonical JSON strings; on mismatch, localize the first
/// divergence with the lazy differ and record a failure.
fn check_json_identical(
    name: &str,
    legacy: &str,
    streamed: &str,
    failures: &mut Vec<String>,
) -> bool {
    if legacy == streamed {
        println!("{name}: byte-identical ({} bytes)", legacy.len());
        return true;
    }
    match json::diff(legacy, streamed) {
        Some(d) => failures.push(format!("{name} diverges at {d}")),
        None => failures.push(format!(
            "{name}: bytes differ but no semantic divergence — \
             whitespace/formatting drift between writers"
        )),
    }
    false
}

/// Compare two CSV strings line-by-line; record the first differing
/// line on mismatch.
fn check_csv_identical(
    name: &str,
    legacy: &str,
    streamed: &str,
    failures: &mut Vec<String>,
) -> bool {
    if legacy == streamed {
        println!("{name}: byte-identical ({} bytes)", legacy.len());
        return true;
    }
    let line = legacy
        .lines()
        .zip(streamed.lines())
        .position(|(a, b)| a != b)
        .map(|i| i + 1)
        .unwrap_or_else(|| {
            legacy.lines().count().min(streamed.lines().count()) + 1
        });
    failures.push(format!("{name} diverges at line {line}"));
    false
}

fn differential(failures: &mut Vec<String>) -> Json {
    section("report_scaling — streaming vs legacy differential");
    let grid = differential_grid();
    let run = tlora::sweep::run(&grid, 2)
        .expect("legacy differential sweep failed");
    let legacy_json = to_json_canonical(&run).to_pretty();
    let legacy_csv = to_csv(&run);

    let mut identical = true;
    for threads in [1usize, 4] {
        let (sj, sc) = stream_outputs(&grid, threads);
        identical &= check_json_identical(
            &format!("canonical JSON (threads {threads})"),
            &legacy_json,
            &sj,
            failures,
        );
        identical &= check_csv_identical(
            &format!("CSV (threads {threads})"),
            &legacy_csv,
            &sc,
            failures,
        );
    }
    Json::obj()
        .set("points", grid.len())
        .set("json_bytes", legacy_json.len())
        .set("csv_bytes", legacy_csv.len())
        .set("identical", identical)
}

// ---- section 2: O(1) allocation gate ------------------------------------

const ALLOC_MAX_RATIO: f64 = 1.5;
const ALLOC_SLACK_BYTES: usize = 64 * 1024;

/// Clone `template` into `n` synthetic point results in one cell
/// (seed varies fastest and is not part of the cell key, so every
/// point lands in the same accumulator).
fn synth_points(template: &PointResult, n: usize) -> Vec<PointResult> {
    (0..n)
        .map(|i| {
            let mut p = template.clone();
            p.point.index = i;
            p.point.seed = template.point.seed + i as u64;
            p
        })
        .collect()
}

fn alloc_gate(failures: &mut Vec<String>) -> Json {
    section("report_scaling — O(1) report-path allocation gate");

    // One small real simulation supplies the template result; the gate
    // measures report-path memory, not simulation cost.
    let mut g = SweepGrid::default();
    g.policies = vec![Policy::TLora];
    g.n_jobs = vec![16];
    g.gpus = vec![16];
    g.rate_scales = vec![2.0];
    g.months = vec![1];
    g.seeds = vec![5];
    let run = tlora::sweep::run(&g, 1)
        .expect("template simulation failed");
    let template = run.points[0].clone();

    let dir = std::env::temp_dir()
        .join(format!("tlora_report_bench_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp dir");

    let mut t = Table::new(
        "streaming report peak memory growth (file sinks)",
        &["points", "peak growth (KiB)", "allocs", "allocs/point"],
    );
    let mut rows = vec![];
    let mut peaks = vec![];
    for p in [16usize, 64, 256] {
        let pts = synth_points(&template, p);
        let jpath = dir.join(format!("out_{p}.json"));
        let cpath = dir.join(format!("out_{p}.csv"));
        let spath = dir.join(format!("spool_{p}.tmp"));
        let mut jout = std::io::BufWriter::new(
            std::fs::File::create(&jpath).expect("json sink"),
        );
        let mut cout = std::io::BufWriter::new(
            std::fs::File::create(&cpath).expect("csv sink"),
        );
        let spool = Spool::file(&spath).expect("spool file");
        let mut report = StreamReport::new(&g, false)
            .with_json(&mut jout, spool)
            .with_csv(&mut cout);

        let live0 = reset_peak();
        let allocs0 = ALLOCS.load(Relaxed);
        for pt in &pts {
            report.point(pt).expect("stream point");
        }
        let cells = report.finish(1, 0.0).expect("stream finish");
        let peak_growth =
            PEAK.load(Relaxed).saturating_sub(live0);
        let allocs = ALLOCS.load(Relaxed) - allocs0;
        assert_eq!(cells.len(), 1, "synthetic points span one cell");
        drop(cells);
        jout.flush().expect("json flush");
        cout.flush().expect("csv flush");

        t.row(&[
            p.to_string(),
            format!("{:.1}", peak_growth as f64 / 1024.0),
            allocs.to_string(),
            format!("{:.0}", allocs as f64 / p as f64),
        ]);
        rows.push(
            Json::obj()
                .set("points", p)
                .set("peak_growth_bytes", peak_growth as u64)
                .set("allocs", allocs as u64),
        );
        peaks.push((p, peak_growth));
    }
    t.print();

    // Legacy tree path at the largest P, informational: it holds every
    // point's JSON tree before writing, so its peak scales with P.
    let pts = synth_points(&template, 256);
    let legacy_run = SweepRun {
        points: pts,
        n_threads: 1,
        wall_s: 0.0,
    };
    let live0 = reset_peak();
    let lj = to_json_canonical(&legacy_run).to_pretty();
    let lc = to_csv(&legacy_run);
    let legacy_peak = PEAK.load(Relaxed).saturating_sub(live0);
    drop((lj, lc));
    println!(
        "legacy tree path at 256 points: {:.1} KiB peak growth \
         (informational)",
        legacy_peak as f64 / 1024.0
    );

    let (small_p, small_peak) = peaks[0];
    let (big_p, big_peak) = *peaks.last().unwrap();
    let bound = small_peak as f64 * ALLOC_MAX_RATIO
        + ALLOC_SLACK_BYTES as f64;
    if big_peak as f64 > bound {
        failures.push(format!(
            "report-path peak memory grew from {small_peak} bytes at \
             P={small_p} to {big_peak} bytes at P={big_p} — exceeds \
             the O(1) bound ({ALLOC_MAX_RATIO}x + \
             {ALLOC_SLACK_BYTES} B slack)"
        ));
    } else {
        println!(
            "gate ok: peak growth {big_peak} B at P={big_p} within \
             {ALLOC_MAX_RATIO}x of {small_peak} B at P={small_p} \
             (+{ALLOC_SLACK_BYTES} B slack)"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);

    Json::obj()
        .set("points", Json::Arr(rows))
        .set("max_ratio", ALLOC_MAX_RATIO)
        .set("slack_bytes", ALLOC_SLACK_BYTES as u64)
        .set("legacy_peak_growth_bytes", legacy_peak as u64)
}

// ---- section 3: arrival-scale smoke -------------------------------------

fn arrival_smoke(failures: &mut Vec<String>) -> Json {
    section("report_scaling — hyperscale arrival generator smoke");
    let full = std::env::var("BENCH_REPORT_FULL")
        .map(|v| v == "1")
        .unwrap_or(false);
    let n: usize = std::env::var("BENCH_REPORT_ARRIVALS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if full { 1_000_000 } else { 100_000 });

    let profile = TraceProfile::hyperscale();
    let (jobs, gen_wall) =
        time_once(|| TraceGenerator::new(profile, 11).generate(n));
    println!(
        "generated {n} arrivals in {gen_wall:.2}s \
         ({:.0} jobs/s)",
        n as f64 / gen_wall.max(1e-9)
    );
    if jobs.len() != n {
        failures.push(format!(
            "generator produced {} jobs, requested {n}",
            jobs.len()
        ));
    }
    if !jobs.windows(2).all(|w| w[0].id < w[1].id) {
        failures.push("generated job ids are not increasing".into());
    }
    // Burst jitter may locally reorder submit times; anything beyond
    // 30 simulated seconds means the arrival process itself broke.
    let disorder = jobs
        .windows(2)
        .filter(|w| w[1].submit_time + 30.0 < w[0].submit_time)
        .count();
    if disorder > 0 {
        failures.push(format!(
            "{disorder} arrival pairs out of order by >30s"
        ));
    }

    // Day/night modulation: the daily sinusoid (phase 0) is above the
    // mean rate for the first half of each period.
    let period = 86_400.0;
    let (mut on, mut off) = (0usize, 0usize);
    for j in &jobs {
        if j.submit_time % period < period / 2.0 {
            on += 1;
        } else {
            off += 1;
        }
    }
    let ratio = on as f64 / off.max(1) as f64;
    println!(
        "diurnal density: {on} on-peak vs {off} off-peak arrivals \
         ({ratio:.2}x)"
    );
    if ratio < 1.2 {
        failures.push(format!(
            "diurnal modulation invisible in arrival density: \
             on/off ratio {ratio:.2} < 1.2"
        ));
    }

    // A modest diurnal simulation with a LoadObserver attached must be
    // byte-free: the observer feeds no SimResult field.
    let mut cfg = ExperimentConfig::default();
    cfg.n_jobs = 240;
    cfg.seed = 11;
    cfg.trace.burst_prob = 0.0;
    cfg.trace.diurnal = Some(DiurnalProfile {
        period_s: 4000.0,
        amplitude: 0.8,
        phase: 0.0,
    });
    let sim_jobs = TraceGenerator::new(cfg.trace.clone(), cfg.seed)
        .generate(cfg.n_jobs);
    let mut load = LoadObserver::new(1000.0);
    let (observed, sim_wall) = time_once(|| {
        simulate_jobs_with(
            &cfg,
            sim_jobs.clone(),
            &EngineOptions::default(),
            &mut [&mut load],
        )
    });
    let bare = simulate_jobs_with(
        &cfg,
        sim_jobs,
        &EngineOptions::default(),
        &mut [],
    );
    if observed.jct != bare.jct || observed.makespan != bare.makespan
    {
        failures.push(
            "LoadObserver perturbed simulation results — observers \
             must be passive"
                .into(),
        );
    }
    if load.bins.is_empty() || load.peak_running() == 0 {
        failures.push(
            "LoadObserver recorded no load bins on a diurnal trace"
                .into(),
        );
    }
    println!(
        "diurnal sim: {} jobs in {sim_wall:.2}s, {} load bins, peak \
         {} running",
        cfg.n_jobs,
        load.bins.len(),
        load.peak_running()
    );

    Json::obj()
        .set("arrivals", n)
        .set("gen_wall_s", gen_wall)
        .set("jobs_per_s", n as f64 / gen_wall.max(1e-9))
        .set("disorder_pairs", disorder as u64)
        .set("diurnal_on_off_ratio", ratio)
        .set("load_bins", load.bins.len())
        .set("peak_running", load.peak_running())
}

fn main() {
    let mut failures: Vec<String> = vec![];
    let differential = differential(&mut failures);
    let alloc_gate = alloc_gate(&mut failures);
    let arrival = arrival_smoke(&mut failures);

    let out_path = std::env::var("BENCH_REPORT_OUT")
        .unwrap_or_else(|_| "BENCH_report.json".into());
    let report = Json::obj()
        .set("differential", differential)
        .set("alloc_gate", alloc_gate)
        .set("arrival_smoke", arrival)
        .set(
            "failures",
            Json::Arr(
                failures
                    .iter()
                    .map(|f| Json::Str(f.clone()))
                    .collect(),
            ),
        );
    match std::fs::write(&out_path, report.to_pretty()) {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => {
            failures.push(format!("could not write {out_path}: {e}"))
        }
    }

    if !failures.is_empty() {
        eprintln!("\nreport_scaling FAILURES:");
        for f in &failures {
            eprintln!("  - {f}");
        }
        std::process::exit(1);
    }
    println!("\nreport_scaling: all checks passed");
}
