//! Figure 8a: impact of nano-batch size — fixed N sweeps vs the AIMD
//! controller. Paper: the adaptive policy consistently beats manually
//! tuned fixed sizes (and the optimum moves with the comm/comp ratio).

use tlora::config::AimdConfig;
use tlora::kernelsim::overlap::{best_fixed_n, iter_time};
use tlora::kernelsim::AimdController;
use tlora::metrics::Table;

fn main() {
    tlora::bench_util::section("Figure 8a — nano-batch size");

    // three group regimes: intra-node (fast), cross-node, congested
    let regimes = [
        ("intra-node", 1.0, 0.25, 0.004, 0.0002),
        ("cross-node", 1.0, 0.70, 0.004, 0.001),
        ("congested", 1.0, 1.40, 0.004, 0.002),
    ];

    let fixed_ns = [1usize, 2, 4, 8, 16, 32, 64];
    let mut t = Table::new(
        "per-step time (s) — fixed N vs AIMD (300-step average)",
        &["regime", "N=1", "N=2", "N=4", "N=8", "N=16", "N=32", "N=64",
          "AIMD", "oracle"],
    );
    let mut aimd_beats_worst_fixed = true;
    let mut aimd_within_oracle = true;
    for &(name, comp, comm, oh, lat) in &regimes {
        let mut cells = vec![name.to_string()];
        let mut best_fixed_t = f64::INFINITY;
        for &n in &fixed_ns {
            let x = iter_time(comp, comm, n, oh, lat);
            best_fixed_t = best_fixed_t.min(x);
            cells.push(format!("{x:.3}"));
        }
        // AIMD average over a 300-step run (includes exploration cost)
        let mut ctl = AimdController::new(AimdConfig::default());
        let mut total = 0.0;
        let steps = 300;
        for _ in 0..steps {
            let x = iter_time(comp, comm, ctl.n(), oh, lat);
            total += x;
            ctl.observe(x);
        }
        let aimd_avg = total / steps as f64;
        let (_, oracle) = best_fixed_n(comp, comm, 64, oh, lat);
        cells.push(format!("{aimd_avg:.3}"));
        cells.push(format!("{oracle:.3}"));
        t.row(&cells);

        let worst_fixed = fixed_ns
            .iter()
            .map(|&n| iter_time(comp, comm, n, oh, lat))
            .fold(0.0f64, f64::max);
        aimd_beats_worst_fixed &= aimd_avg < worst_fixed;
        aimd_within_oracle &= aimd_avg < oracle * 1.15;
    }
    t.print();

    println!(
        "\npaper shape: no single fixed N wins everywhere; AIMD tracks \
         the per-regime optimum -> {}",
        if aimd_beats_worst_fixed && aimd_within_oracle {
            "REPRODUCED (AIMD within 15% of oracle in every regime)"
        } else {
            "NOT reproduced"
        }
    );
}
