//! Figure 9a (+ Figure 12): impact of system load — replaying the trace
//! with scaled inter-arrival times (0.5×, 1×, 2×, 5×). Paper: tLoRA
//! sustains 1.2–1.8× better throughput than the baselines across loads;
//! denser arrivals stretch JCT (queueing), sparser arrivals trade a
//! little throughput for shorter JCT.

use tlora::config::{ExperimentConfig, Policy};
use tlora::metrics::{cdf_block, write_report, Table};
use tlora::sim::simulate;
use tlora::util::stats::Cdf;
use tlora::workload::trace::TraceProfile;

fn main() {
    tlora::bench_util::section("Figure 9a / 12 — arrival-rate scaling");
    let scales = [0.5, 1.0, 2.0, 5.0];

    let mut t = Table::new(
        "throughput (samples/s) and mean JCT (s) by arrival scale",
        &["scale", "tLoRA thr", "mLoRA thr", "Mega thr", "tLoRA/mLoRA",
          "tLoRA JCT", "mLoRA JCT"],
    );
    let mut all_hold = true;
    let mut cdfs = String::new();
    for &scale in &scales {
        let run = |policy: Policy| {
            let mut cfg = ExperimentConfig::default();
            cfg.n_jobs = 200;
            cfg.policy = policy;
            cfg.trace = TraceProfile::month1().scaled(scale);
            simulate(&cfg)
        };
        let tl = run(Policy::TLora);
        let ml = run(Policy::MLora);
        let mg = run(Policy::Megatron);
        let ratio = tl.avg_throughput / ml.avg_throughput;
        all_hold &= ratio >= 1.05;
        t.row(&[
            format!("{scale}x"),
            format!("{:.2}", tl.avg_throughput),
            format!("{:.2}", ml.avg_throughput),
            format!("{:.2}", mg.avg_throughput),
            format!("{ratio:.2}x"),
            format!("{:.0}", tl.mean_jct),
            format!("{:.0}", ml.mean_jct),
        ]);
        cdfs.push_str(&cdf_block(
            &format!("tLoRA-{scale}x"),
            &Cdf::of(&tl.jct_values(), 50),
        ));
        cdfs.push('\n');
    }
    t.print();
    println!(
        "\npaper shape: consistent 1.2-1.8x throughput advantage across \
         loads -> {}",
        if all_hold { "REPRODUCED" } else { "PARTIAL" }
    );
    if let Some(p) = write_report("fig12_jct_by_rate.txt", &cdfs) {
        println!("Fig 12 JCT CDFs -> {}", p.display());
    }
}
