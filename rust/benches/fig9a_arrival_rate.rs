//! Figure 9a (+ Figure 12): impact of system load — replaying the trace
//! with scaled inter-arrival times (0.5×, 1×, 2×, 5×). Paper: tLoRA
//! sustains 1.2–1.8× better throughput than the baselines across loads;
//! denser arrivals stretch JCT (queueing), sparser arrivals trade a
//! little throughput for shorter JCT.
//!
//! Thin driver over the sweep engine: 3 policies × 4 rate scales run as
//! one parallel grid.

use tlora::config::Policy;
use tlora::metrics::{cdf_block, write_report, Table};
use tlora::sweep::{run_parallel, SweepGrid};
use tlora::util::stats::Cdf;

fn main() {
    tlora::bench_util::section("Figure 9a / 12 — arrival-rate scaling");
    let scales = [0.5, 1.0, 2.0, 5.0];

    let mut grid = SweepGrid::default();
    grid.policies =
        vec![Policy::TLora, Policy::MLora, Policy::Megatron];
    grid.n_jobs = vec![200];
    grid.rate_scales = scales.to_vec();
    let run = run_parallel(&grid).expect("sweep failed");
    println!(
        "({} sims in {:.2}s on {} threads)",
        run.points.len(),
        run.wall_s,
        run.n_threads
    );

    let mut t = Table::new(
        "throughput (samples/s) and mean JCT (s) by arrival scale",
        &["scale", "tLoRA thr", "mLoRA thr", "Mega thr", "tLoRA/mLoRA",
          "tLoRA JCT", "mLoRA JCT"],
    );
    let mut all_hold = true;
    let mut cdfs = String::new();
    for &scale in &scales {
        let at = |policy: Policy| {
            &run.expect_one(|p| {
                p.policy == policy && p.rate_scale == scale
            })
            .result
        };
        let tl = at(Policy::TLora);
        let ml = at(Policy::MLora);
        let mg = at(Policy::Megatron);
        let ratio = tl.avg_throughput / ml.avg_throughput;
        all_hold &= ratio >= 1.05;
        t.row(&[
            format!("{scale}x"),
            format!("{:.2}", tl.avg_throughput),
            format!("{:.2}", ml.avg_throughput),
            format!("{:.2}", mg.avg_throughput),
            format!("{ratio:.2}x"),
            format!("{:.0}", tl.mean_jct),
            format!("{:.0}", ml.mean_jct),
        ]);
        cdfs.push_str(&cdf_block(
            &format!("tLoRA-{scale}x"),
            &Cdf::of(&tl.jct_values(), 50),
        ));
        cdfs.push('\n');
    }
    t.print();
    println!(
        "\npaper shape: consistent 1.2-1.8x throughput advantage across \
         loads -> {}",
        if all_hold { "REPRODUCED" } else { "PARTIAL" }
    );
    if let Some(p) = write_report("fig12_jct_by_rate.txt", &cdfs) {
        println!("Fig 12 JCT CDFs -> {}", p.display());
    }
}
