//! Figure 9b (+ Figure 13): impact of cluster size — same workload on
//! 32/64/128/256 GPUs. Paper: throughput scales with capacity, JCT
//! curves shift right in consistent intervals as the cluster shrinks
//! (no starvation / heavy-tail collapse at 32 GPUs).
//!
//! Thin driver over the sweep engine: the four cluster sizes run as one
//! parallel grid.

use tlora::metrics::{cdf_block, write_report, Table};
use tlora::sweep::{run_parallel, SweepGrid};
use tlora::util::stats::Cdf;

fn main() {
    tlora::bench_util::section("Figure 9b / 13 — cluster size");
    let sizes = [32usize, 64, 128, 256];

    let mut grid = SweepGrid::default();
    grid.n_jobs = vec![200];
    grid.gpus = sizes.to_vec();
    let run = run_parallel(&grid).expect("sweep failed");
    println!(
        "({} sims in {:.2}s on {} threads)",
        run.points.len(),
        run.wall_s,
        run.n_threads
    );

    let mut t = Table::new(
        "tLoRA across cluster sizes (200 jobs, month-1 trace)",
        &["GPUs", "thr (samples/s)", "mean JCT (s)", "p99 JCT (s)",
          "p99/mean", "util"],
    );
    let mut results = vec![];
    for &n in &sizes {
        let r = run.expect_one(|p| p.gpus == n).result.clone();
        t.row(&[
            n.to_string(),
            format!("{:.2}", r.avg_throughput),
            format!("{:.0}", r.mean_jct),
            format!("{:.0}", r.p99_jct),
            format!("{:.1}", r.p99_jct / r.mean_jct.max(1e-9)),
            format!("{:.1}%", r.avg_gpu_util * 100.0),
        ]);
        results.push((n, r));
    }
    t.print();

    // shape checks: throughput non-decreasing with size; JCT
    // non-increasing; tails bounded (p99/mean stays sane at 32 GPUs)
    let thr_monotone = results
        .windows(2)
        .all(|w| w[1].1.avg_throughput >= w[0].1.avg_throughput * 0.9);
    let jct_monotone = results
        .windows(2)
        .all(|w| w[1].1.mean_jct <= w[0].1.mean_jct * 1.1);
    let tail_bounded =
        results[0].1.p99_jct / results[0].1.mean_jct.max(1e-9) < 20.0;
    println!(
        "\npaper shape: proportional scaling, consistent JCT shift, no \
         heavy-tail collapse at 32 GPUs -> {}",
        if thr_monotone && jct_monotone && tail_bounded {
            "REPRODUCED"
        } else {
            "PARTIAL"
        }
    );

    let mut blocks = String::new();
    for (n, r) in &results {
        blocks.push_str(&cdf_block(
            &format!("{n}gpus"),
            &Cdf::of(&r.jct_values(), 50),
        ));
        blocks.push('\n');
    }
    if let Some(p) = write_report("fig13_jct_by_cluster.txt", &blocks) {
        println!("Fig 13 JCT CDFs -> {}", p.display());
    }
}
