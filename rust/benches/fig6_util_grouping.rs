//! Figure 6a: GPU utilization improvement from heterogeneity-aware
//! co-location (paper: up to +37%).
//! Figure 6b: grouping-ratio breakdown by job size class — which job
//! sizes actually get co-located under tLoRA vs mLoRA's FIFO packing.

use tlora::config::{ExperimentConfig, Policy};
use tlora::metrics::Table;
use tlora::sim::simulate;

fn main() {
    tlora::bench_util::section("Figure 6 — utilization & grouping");
    let mut base = ExperimentConfig::default();
    base.n_jobs = 200;

    let mut util = Table::new(
        "Fig 6a — average GPU utilization",
        &["policy", "GPU util", "vs Megatron"],
    );
    let mut mega_util = 0.0;
    let mut rows = vec![];
    for policy in [Policy::Megatron, Policy::MLora, Policy::TLora] {
        let mut cfg = base.clone();
        cfg.policy = policy;
        let r = simulate(&cfg);
        if policy == Policy::Megatron {
            mega_util = r.avg_gpu_util;
        }
        rows.push((policy, r));
    }
    for (policy, r) in &rows {
        util.row(&[
            policy.name().to_string(),
            format!("{:.1}%", r.avg_gpu_util * 100.0),
            format!(
                "{}{:.0}%",
                if r.avg_gpu_util >= mega_util { "+" } else { "" },
                (r.avg_gpu_util / mega_util - 1.0) * 100.0
            ),
        ]);
    }
    util.print();
    let tl = &rows.last().unwrap().1;
    println!(
        "paper: up to +37% utilization; measured tLoRA vs Megatron: \
         {:+.0}%\n",
        (tl.avg_gpu_util / mega_util - 1.0) * 100.0
    );

    let mut grp = Table::new(
        "Fig 6b — fraction of running time spent co-located, by size class",
        &["policy", "small", "medium", "large"],
    );
    for (policy, r) in &rows {
        if *policy == Policy::Megatron {
            continue;
        }
        let g = |k: &str| {
            format!(
                "{:.0}%",
                r.grouping_ratio.get(k).copied().unwrap_or(0.0) * 100.0
            )
        };
        grp.row(&[
            policy.name().to_string(),
            g("small"),
            g("medium"),
            g("large"),
        ]);
    }
    grp.print();
    let ratio = |r: &tlora::sim::SimResult, k: &str| {
        r.grouping_ratio.get(k).copied().unwrap_or(0.0)
    };
    let small = ratio(tl, "small");
    let med = ratio(tl, "medium");
    let large = ratio(tl, "large");
    println!(
        "\npaper shape (saturated jobs offer the least co-location \
         benefit and group least) -> {}",
        if large <= small && large <= med {
            "REPRODUCED"
        } else {
            "NOT reproduced"
        }
    );
    println!(
        "divergence note: the paper pairs small WITH large (elastic \
         contribution); under our bounded-slowdown model a small job \
         tied to a large job's cadence violates its Δ^max, so small \
         jobs pair with small/medium instead — see EXPERIMENTS.md §Fig6b."
    );
}
