//! L3 scheduler scaling (§3.4 complexity claim + §Perf deliverable),
//! promoted into the seeded, deterministic scaling suite behind the
//! `bench-sched` CI job:
//!
//! 1. **Round microbench** — one hierarchical-incremental-grouping
//!    round at K ∈ {100, 400, 1600} jobs must scale ~O(K log K), not
//!    quadratically (probes/job stays flat-ish).
//! 2. **End-to-end scaling grid** — full simulations at 128→1024 GPUs,
//!    dense and sparse arrival, faults + stragglers on, pinned seed.
//!    Every scenario row (wall_s, planner probes, cache hit-rate,
//!    events popped, stale discards) is emitted to `BENCH_sched.json`
//!    (path override: `BENCH_SCHED_OUT`).
//! 3. **Cache-effectiveness check** — the pinned dense-arrival
//!    scenario re-run with the shape cache disabled must cost ≥30%
//!    more planner evaluations (the acceptance bar for the two-level
//!    predictor cache).
//! 4. **Probe gate** — the pinned scenario's `scheduler_probes` is
//!    compared against the committed baseline
//!    (`benches/baselines/sched_scaling_baseline.json`, override:
//!    `BENCH_SCHED_BASELINE`); >5% growth fails the run. The baseline
//!    self-blesses on first run (mirroring the golden-fixture
//!    protocol): while it holds the `UNBLESSED` sentinel the bench
//!    writes the measured value and passes — commit the result to arm
//!    the gate.
//! 5. **Thread determinism** — a multi-cell pinned grid run at
//!    threads 1 and 8 must emit byte-identical canonical JSON.
//!
//! Any check failure exits nonzero, so the CI job is a real gate.

use tlora::bench_util::{bench, section, time_once};
use tlora::cluster::{Allocator, ClusterSpec};
use tlora::config::{Policy, SchedulerConfig};
use tlora::metrics::Table;
use tlora::planner::PlanOptions;
use tlora::scheduler::predictor::Predictor;
use tlora::scheduler::{schedule, Candidate};
use tlora::sim::{simulate_jobs_with, EngineOptions, SimResult};
use tlora::sweep::{run as sweep_run, to_json_canonical, SweepGrid};
use tlora::util::json::{self, Json};
use tlora::workload::trace::{TraceGenerator, TraceProfile};

const SEED: u64 = 42;

fn mk_candidates(k: usize, n_gpus: usize) -> Vec<Candidate> {
    let spec = ClusterSpec::with_gpus(n_gpus);
    let mut alloc = Allocator::new(spec.clone());
    let mut pred = Predictor::new(spec, PlanOptions::default());
    let jobs =
        TraceGenerator::new(TraceProfile::month1(), 7).generate(k);
    jobs.into_iter()
        .filter_map(|mut j| {
            j.gpus = 1; // stress the grouping logic, not the allocator
            let a = alloc.allocate(1)?;
            let residual = pred.residual(&j, &a).unwrap_or(0.5);
            Some(Candidate {
                job: j,
                alloc: a,
                urgency: 0.0,
                residual,
            })
        })
        .collect()
}

/// The round microbench: probes/job must stay quasi-flat with K.
fn round_microbench(failures: &mut Vec<String>) -> Vec<Json> {
    section("sched_scaling — O(K log K) grouping round");
    let mut t = Table::new(
        "one scheduling round",
        &["K jobs", "time (ms)", "ms/job", "probes", "cache hits",
          "probes/job"],
    );
    let mut rows = vec![];
    let mut per_job_times = vec![];
    let mut per_job_probes = vec![];
    for k in [100usize, 400, 1600] {
        let cands = mk_candidates(k, 2 * k);
        let spec = ClusterSpec::with_gpus(2 * k);
        let cfg = SchedulerConfig::default();
        let mut probes = 0u64;
        let mut hits = 0u64;
        let r = bench(&format!("round K={k}"), 1, 3, || {
            let mut pred =
                Predictor::new(spec.clone(), PlanOptions::default());
            let out = schedule(cands.clone(), &mut pred, &cfg);
            probes = out.predictor_probes;
            hits = out.plan_cache_hits;
            out.groups.len()
        });
        let ms_per_job = r.mean_ms() / k as f64;
        per_job_times.push((k, ms_per_job));
        per_job_probes.push((k, probes as f64 / k as f64));
        t.row(&[
            k.to_string(),
            format!("{:.1}", r.mean_ms()),
            format!("{ms_per_job:.3}"),
            probes.to_string(),
            hits.to_string(),
            format!("{:.1}", probes as f64 / k as f64),
        ]);
        rows.push(
            Json::obj()
                .set("k", k)
                .set("mean_ms", r.mean_ms())
                .set("probes", probes)
                .set("plan_cache_hits", hits),
        );
    }
    t.print();

    // O(K log K) means per-job cost grows ~log K: going 100 -> 1600
    // (16x jobs) should grow it by far less than 16x. The *gate* is
    // the deterministic probes/job ratio — wall-clock on a shared CI
    // runner is noise-prone (3 reps) and stays informational only.
    let time_growth = per_job_times.last().unwrap().1
        / per_job_times.first().unwrap().1.max(1e-9);
    let probe_growth = per_job_probes.last().unwrap().1
        / per_job_probes.first().unwrap().1.max(1e-9);
    println!(
        "\nper-job growth 100->1600 jobs: {probe_growth:.1}x probes \
         (gated), {time_growth:.1}x wall time (informational; \
         quadratic would be ~16x)"
    );
    if probe_growth >= 8.0 {
        failures.push(format!(
            "grouping round probes/job grew {probe_growth:.1}x from \
             K=100 to K=1600 (quasi-linear bound is 8x)"
        ));
    }
    rows
}

/// One end-to-end scenario of the scaling grid.
struct Scenario {
    gpus: usize,
    n_jobs: usize,
    rate_scale: f64,
    kind: &'static str,
}

impl Scenario {
    fn label(&self) -> String {
        format!(
            "tlora/g{}/j{}/r{}x/{}+faults+stragglers",
            self.gpus, self.n_jobs, self.rate_scale, self.kind
        )
    }

    /// A one-cell grid: faults + stragglers on, pinned seed.
    fn grid(&self) -> SweepGrid {
        let mut g = SweepGrid::default();
        g.policies = vec![Policy::TLora];
        g.n_jobs = vec![self.n_jobs];
        g.gpus = vec![self.gpus];
        g.rate_scales = vec![self.rate_scale];
        g.months = vec![1];
        g.mtbfs = vec![3600.0];
        g.stragglers = vec![1800.0];
        g.seeds = vec![SEED];
        g
    }
}

fn scenarios() -> Vec<Scenario> {
    let mut out = vec![];
    for &gpus in &[128usize, 256, 512, 1024] {
        // job count scales with the cluster; capped so the largest
        // dense cell stays CI-sized
        let n_jobs = (gpus / 4).min(192);
        out.push(Scenario { gpus, n_jobs, rate_scale: 4.0, kind: "dense" });
        out.push(Scenario { gpus, n_jobs, rate_scale: 0.5, kind: "sparse" });
    }
    out
}

/// The gated scenario: dense arrival at 256 GPUs.
fn pinned(scens: &[Scenario]) -> &Scenario {
    scens
        .iter()
        .find(|s| s.gpus == 256 && s.kind == "dense")
        .expect("pinned scenario missing from the scaling grid")
}

fn scenario_json(s: &Scenario, r: &SimResult, wall_s: f64) -> Json {
    Json::obj()
        .set("label", s.label())
        .set("gpus", s.gpus)
        .set("n_jobs", s.n_jobs)
        .set("rate_scale", s.rate_scale)
        .set("wall_s", wall_s)
        .set("scheduler_probes", r.scheduler_probes)
        .set("plan_cache_hits", r.plan_cache_hits)
        .set("plan_cache_rate", r.plan_cache_rate())
        .set("sched_rounds", r.sched_rounds)
        .set("events", r.events)
        .set("events_stale", r.events_stale)
        .set("completed", r.jct.len())
        .set("incomplete", r.incomplete_jobs.len())
}

fn run_scenario(s: &Scenario, opts: &EngineOptions) -> (SimResult, f64) {
    let grid = s.grid();
    let points = grid.points();
    let cfg = points[0].config(&grid.base);
    let jobs = TraceGenerator::new(cfg.trace.clone(), cfg.seed)
        .generate(cfg.n_jobs);
    time_once(|| simulate_jobs_with(&cfg, jobs, opts, &mut []))
}

fn main() {
    let mut failures: Vec<String> = vec![];
    let round_rows = round_microbench(&mut failures);

    section("sched_scaling — end-to-end scaling grid (faults+stragglers)");
    let scens = scenarios();
    let mut t = Table::new(
        "cluster scaling, pinned seed",
        &["scenario", "wall (s)", "probes", "hit%", "rounds",
          "events", "stale", "incomplete"],
    );
    let mut rows = vec![];
    let mut pinned_result: Option<SimResult> = None;
    for s in &scens {
        let (r, wall_s) = run_scenario(s, &EngineOptions::default());
        let hit_pct = 100.0 * r.plan_cache_rate();
        t.row(&[
            s.label(),
            format!("{wall_s:.2}"),
            r.scheduler_probes.to_string(),
            format!("{hit_pct:.1}"),
            r.sched_rounds.to_string(),
            r.events.to_string(),
            r.events_stale.to_string(),
            r.incomplete_jobs.len().to_string(),
        ]);
        rows.push(scenario_json(s, &r, wall_s));
        if s.gpus == pinned(&scens).gpus && s.kind == "dense" {
            pinned_result = Some(r);
        }
    }
    t.print();
    let pinned_scen = pinned(&scens);
    let pinned_result = pinned_result.expect("pinned scenario not run");

    // ---- cache effectiveness: cold re-run of the pinned scenario ----
    section("sched_scaling — shape-cache effectiveness (pinned cell)");
    let (cold, cold_wall) = run_scenario(
        pinned_scen,
        &EngineOptions {
            plan_shape_cache: false,
            ..EngineOptions::default()
        },
    );
    let warm_probes = pinned_result.scheduler_probes;
    let cold_probes = cold.scheduler_probes;
    let drop = if cold_probes == 0 {
        0.0
    } else {
        1.0 - warm_probes as f64 / cold_probes as f64
    };
    println!(
        "pinned {}: warm {} probes vs cold {} ({:.1}% drop, cold \
         wall {:.2}s)",
        pinned_scen.label(),
        warm_probes,
        cold_probes,
        drop * 100.0,
        cold_wall
    );
    if drop < 0.30 {
        failures.push(format!(
            "shape cache dropped only {:.1}% of planner evaluations \
             on the pinned dense scenario (acceptance bar: 30%)",
            drop * 100.0
        ));
    }

    // ---- probe gate vs the committed baseline ----
    section("sched_scaling — probe-count gate");
    let baseline_path = std::env::var("BENCH_SCHED_BASELINE")
        .unwrap_or_else(|_| {
            "benches/baselines/sched_scaling_baseline.json".into()
        });
    let mut gate = Json::obj()
        .set("pinned", pinned_scen.label())
        .set("scheduler_probes", warm_probes)
        .set("cold_probes", cold_probes)
        .set("max_growth", 0.05);
    let baseline = std::fs::read_to_string(&baseline_path).ok();
    match baseline.filter(|s| !s.contains("UNBLESSED")) {
        Some(text) => match json::parse(&text) {
            Ok(b) => {
                let base_label = b
                    .get("pinned")
                    .and_then(Json::as_str)
                    .unwrap_or_default()
                    .to_string();
                let base_probes = b
                    .get("scheduler_probes")
                    .and_then(Json::as_i64)
                    .filter(|&n| n > 0)
                    .map(|n| n as u64);
                match base_probes {
                    None => {
                        // a blessed baseline without a positive probe
                        // count is a broken file, not a probe
                        // regression — fail with the actual cause
                        failures.push(format!(
                            "baseline {baseline_path} lacks a positive \
                             integer scheduler_probes field — re-bless \
                             it (restore the UNBLESSED sentinel and \
                             re-run)"
                        ));
                    }
                    Some(base_probes)
                        if base_label != pinned_scen.label() =>
                    {
                        gate = gate
                            .set("baseline_probes", base_probes);
                        failures.push(format!(
                            "baseline pins scenario {base_label:?} but \
                             the suite's pinned cell is {:?} — \
                             re-bless {baseline_path}",
                            pinned_scen.label()
                        ));
                    }
                    Some(base_probes) => {
                        gate = gate
                            .set("baseline_probes", base_probes);
                        if warm_probes as f64
                            > base_probes as f64 * 1.05
                        {
                            failures.push(format!(
                                "scheduler_probes regressed: \
                                 {warm_probes} vs baseline \
                                 {base_probes} (>5% growth) — \
                                 investigate before re-blessing \
                                 {baseline_path}"
                            ));
                        } else {
                            println!(
                                "gate ok: {warm_probes} probes vs \
                                 baseline {base_probes} (≤5% growth \
                                 allowed)"
                            );
                            if (warm_probes as f64)
                                < base_probes as f64 * 0.95
                            {
                                println!(
                                    "note: probes dropped >5% below \
                                     baseline — consider re-blessing \
                                     to tighten the gate"
                                );
                            }
                        }
                    }
                }
            }
            Err(e) => failures.push(format!(
                "baseline {baseline_path} is not valid JSON: {e:?}"
            )),
        },
        None => {
            // first run on this checkout: bless the measured value
            let blessed = Json::obj()
                .set("pinned", pinned_scen.label())
                .set("scheduler_probes", warm_probes)
                .to_pretty();
            if let Some(dir) =
                std::path::Path::new(&baseline_path).parent()
            {
                let _ = std::fs::create_dir_all(dir);
            }
            match std::fs::write(&baseline_path, &blessed) {
                Ok(()) => println!(
                    "baseline blessed at {baseline_path} \
                     ({warm_probes} probes); commit it to arm the gate"
                ),
                Err(e) => failures.push(format!(
                    "could not bless baseline {baseline_path}: {e}"
                )),
            }
            gate = gate.set("blessed", true);
        }
    }

    // ---- thread determinism: canonical bytes at threads 1 vs 8 ----
    section("sched_scaling — threads 1 vs 8 canonical diff");
    let mut det_grid = SweepGrid::default();
    det_grid.policies = vec![Policy::TLora, Policy::Megatron];
    det_grid.n_jobs = vec![24];
    det_grid.gpus = vec![128];
    det_grid.rate_scales = vec![4.0];
    det_grid.months = vec![1];
    det_grid.mtbfs = vec![3600.0];
    det_grid.stragglers = vec![1800.0];
    det_grid.seeds = vec![SEED, SEED + 1];
    let t1 = to_json_canonical(&sweep_run(&det_grid, 1).unwrap())
        .to_pretty();
    let t8 = to_json_canonical(&sweep_run(&det_grid, 8).unwrap())
        .to_pretty();
    let identical = t1 == t8;
    if identical {
        println!("canonical JSON byte-identical at threads 1 and 8");
    } else {
        failures.push(
            "canonical sweep JSON differs between threads 1 and 8"
                .into(),
        );
    }

    // ---- emit BENCH_sched.json ----
    let out_path = std::env::var("BENCH_SCHED_OUT")
        .unwrap_or_else(|_| "BENCH_sched.json".into());
    let report = Json::obj()
        .set("seed", SEED)
        .set("round_microbench", Json::Arr(round_rows))
        .set("scenarios", Json::Arr(rows))
        .set("gate", gate)
        .set(
            "determinism",
            Json::obj()
                .set("threads", Json::Arr(vec![
                    Json::Int(1),
                    Json::Int(8),
                ]))
                .set("identical", identical),
        )
        .set("failures", Json::Arr(
            failures.iter().map(|f| Json::Str(f.clone())).collect(),
        ));
    match std::fs::write(&out_path, report.to_pretty()) {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => failures.push(format!("could not write {out_path}: {e}")),
    }

    if !failures.is_empty() {
        eprintln!("\nsched_scaling FAILURES:");
        for f in &failures {
            eprintln!("  - {f}");
        }
        std::process::exit(1);
    }
    println!("\nsched_scaling: all checks passed");
}
