//! L3 scheduler scaling (§3.4 complexity claim + §Perf deliverable):
//! one hierarchical-incremental-grouping round at K ∈ {100, 400, 1600}
//! jobs must scale ~O(K log K), not quadratically, and the simulator's
//! event loop must sustain a high horizon rate.

use tlora::bench_util::{bench, section};
use tlora::cluster::{Allocator, ClusterSpec};
use tlora::config::SchedulerConfig;
use tlora::metrics::Table;
use tlora::planner::PlanOptions;
use tlora::scheduler::predictor::Predictor;
use tlora::scheduler::{schedule, Candidate};
use tlora::workload::trace::{TraceGenerator, TraceProfile};

fn mk_candidates(k: usize, n_gpus: usize) -> Vec<Candidate> {
    let spec = ClusterSpec::with_gpus(n_gpus);
    let mut alloc = Allocator::new(spec.clone());
    let mut pred = Predictor::new(spec, PlanOptions::default());
    let jobs =
        TraceGenerator::new(TraceProfile::month1(), 7).generate(k);
    jobs.into_iter()
        .filter_map(|mut j| {
            j.gpus = 1; // stress the grouping logic, not the allocator
            let a = alloc.allocate(1)?;
            let residual = pred.residual(&j, &a).unwrap_or(0.5);
            Some(Candidate {
                job: j,
                alloc: a,
                urgency: 0.0,
                residual,
            })
        })
        .collect()
}

fn main() {
    section("sched_scaling — O(K log K) grouping round");
    let mut t = Table::new(
        "one scheduling round",
        &["K jobs", "time (ms)", "ms/job", "probes", "probes/job"],
    );
    let mut per_job_times = vec![];
    for k in [100usize, 400, 1600] {
        let cands = mk_candidates(k, 2 * k);
        let spec = ClusterSpec::with_gpus(2 * k);
        let cfg = SchedulerConfig::default();
        let mut probes = 0u64;
        let r = bench(&format!("round K={k}"), 1, 3, || {
            let mut pred =
                Predictor::new(spec.clone(), PlanOptions::default());
            let out = schedule(cands.clone(), &mut pred, &cfg);
            probes = out.predictor_probes;
            out.groups.len()
        });
        let ms_per_job = r.mean_ms() / k as f64;
        per_job_times.push((k, ms_per_job));
        t.row(&[
            k.to_string(),
            format!("{:.1}", r.mean_ms()),
            format!("{ms_per_job:.3}"),
            probes.to_string(),
            format!("{:.1}", probes as f64 / k as f64),
        ]);
    }
    t.print();

    // O(K log K) means ms/job grows ~log K: going 100 -> 1600 (16x jobs)
    // should grow per-job cost by far less than 16x (quadratic blowup)
    let growth = per_job_times.last().unwrap().1
        / per_job_times.first().unwrap().1.max(1e-9);
    println!(
        "\nper-job cost growth 100->1600 jobs: {growth:.1}x \
         (quadratic would be ~16x) -> {}",
        if growth < 8.0 { "quasi-linear OK" } else { "TOO STEEP" }
    );
}
