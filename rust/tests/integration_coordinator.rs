//! Integration: the coordinator's leader/executor topology over the
//! real PJRT runtime — fused multi-job runs with heterogeneous budgets,
//! elastic slot retirement, and clean shutdown.

use std::path::PathBuf;

use tlora::coordinator::{run_fused_jobs, Coordinator, FusedJob};

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("artifacts/ missing — skipping coordinator integration");
        None
    }
}

#[test]
fn spawn_step_shutdown() {
    let Some(dir) = artifacts() else { return };
    let coord = Coordinator::spawn(dir, "tiny".into(), 0).unwrap();
    let info = coord.variant_info().unwrap();
    assert_eq!(info.num_adapters, 4);
    let b: usize = info.batch_sizes.iter().sum();
    let tokens = vec![1i32; b * info.seq_len];
    let ids: Vec<i32> =
        (0..b as i32).map(|i| i % info.num_adapters as i32).collect();
    let s = coord.step(tokens, ids).unwrap();
    assert!(s.loss.is_finite());
    assert_eq!(s.per_adapter_loss.len(), 4);
    coord.shutdown();
}

#[test]
fn heterogeneous_budgets_retire_elastically() {
    let Some(dir) = artifacts() else { return };
    let coord = Coordinator::spawn(dir, "tiny".into(), 1).unwrap();
    let jobs = vec![
        FusedJob { adapter_slot: 0, steps: 3 },
        FusedJob { adapter_slot: 1, steps: 10 },
        FusedJob { adapter_slot: 2, steps: 6 },
    ];
    let report = run_fused_jobs(&coord, &jobs, 42, 2).unwrap();
    // the group runs until the longest budget completes
    assert_eq!(report.fused_steps, 10);
    for (slot, steps, loss) in &report.jobs {
        let want = jobs.iter().find(|j| j.adapter_slot == *slot).unwrap();
        assert_eq!(*steps, want.steps, "slot {slot}");
        assert!(loss.is_finite());
    }
    coord.shutdown();
}

#[test]
fn rejects_out_of_range_slot() {
    let Some(dir) = artifacts() else { return };
    let coord = Coordinator::spawn(dir, "tiny".into(), 2).unwrap();
    let jobs = vec![FusedJob { adapter_slot: 9, steps: 1 }];
    assert!(run_fused_jobs(&coord, &jobs, 1, 1).is_err());
}

#[test]
fn step_rejects_malformed_batches() {
    let Some(dir) = artifacts() else { return };
    let coord = Coordinator::spawn(dir, "tiny".into(), 3).unwrap();
    // wrong token count
    assert!(coord.step(vec![0i32; 7], vec![0i32; 8]).is_err());
    // executor must survive the error and keep serving
    let info = coord.variant_info().unwrap();
    let b: usize = info.batch_sizes.iter().sum();
    let tokens = vec![0i32; b * info.seq_len];
    let ids = vec![0i32; b];
    assert!(coord.step(tokens, ids).is_ok());
}

#[test]
fn unknown_variant_fails_cleanly() {
    let Some(dir) = artifacts() else { return };
    assert!(Coordinator::spawn(dir, "no-such-variant".into(), 0).is_err());
}
