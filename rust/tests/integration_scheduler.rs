//! Integration + property tests: the Adapter Scheduler's §3.4
//! invariants over randomized workloads, via the in-crate prop
//! framework (proptest substitute).

use tlora::cluster::{Allocator, ClusterSpec};
use tlora::config::SchedulerConfig;
use tlora::planner::PlanOptions;
use tlora::scheduler::predictor::Predictor;
use tlora::scheduler::{schedule, Candidate};
use tlora::util::prop::{gen_usize, prop_check};
use tlora::util::rng::Rng;
use tlora::workload::trace::{TraceGenerator, TraceProfile};
use tlora::workload::JobSpec;

fn candidates_from_seed(seed: u64, k: usize)
    -> (Vec<Candidate>, Predictor, SchedulerConfig) {
    let spec = ClusterSpec::with_gpus((4 * k).max(16));
    let mut alloc = Allocator::new(spec.clone());
    let mut pred = Predictor::new(spec, PlanOptions::default());
    let mut rng = Rng::new(seed);
    let jobs: Vec<JobSpec> =
        TraceGenerator::new(TraceProfile::month1(), seed).generate(k);
    let cands = jobs
        .into_iter()
        .filter_map(|mut j| {
            j.gpus = *rng.choice(&[1usize, 1, 2]);
            let a = alloc.allocate(j.gpus)?;
            let residual = pred.residual(&j, &a).unwrap_or(0.5);
            Some(Candidate {
                job: j,
                alloc: a,
                urgency: rng.f64(),
                residual,
            })
        })
        .collect();
    (cands, pred, SchedulerConfig::default())
}

#[test]
fn prop_every_job_scheduled_exactly_once() {
    prop_check(15, &gen_usize(1, 5000), |&seed| {
        let (cands, mut pred, cfg) = candidates_from_seed(seed as u64, 10);
        let n = cands.len();
        let mut ids: Vec<u64> =
            cands.iter().map(|c| c.job.id).collect();
        let out = schedule(cands, &mut pred, &cfg);
        let mut got: Vec<u64> = out
            .groups
            .iter()
            .flat_map(|(g, _)| g.jobs.iter().map(|j| j.id))
            .collect();
        ids.sort_unstable();
        got.sort_unstable();
        got.len() == n && got == ids
    });
}

#[test]
fn prop_groups_respect_size_memory_and_slowdown() {
    prop_check(15, &gen_usize(1, 5000), |&seed| {
        let (cands, mut pred, cfg) = candidates_from_seed(seed as u64, 12);
        let out = schedule(cands, &mut pred, &cfg);
        out.groups.iter().all(|(g, perf)| {
            g.jobs.len() <= cfg.max_group_size
                && perf.within_slowdown(&g.jobs)
                && g.jobs
                    .iter()
                    .all(|j| j.base_model == g.jobs[0].base_model)
        })
    });
}

#[test]
fn prop_grouping_never_reduces_aggregate_throughput() {
    prop_check(10, &gen_usize(1, 5000), |&seed| {
        let (cands, mut pred, cfg) = candidates_from_seed(seed as u64, 8);
        // isolated aggregate
        let iso: f64 = cands
            .iter()
            .cloned()
            .filter_map(|c| {
                pred.group_perf(std::slice::from_ref(&c.job), &c.alloc)
                    .map(|p| p.throughput_samples_s)
            })
            .sum();
        let out = schedule(cands, &mut pred, &cfg);
        let grouped: f64 = out
            .groups
            .iter()
            .map(|(_, p)| p.throughput_samples_s)
            .sum();
        grouped >= iso * 0.999
    });
}

#[test]
fn prop_allocations_never_shared_between_groups() {
    prop_check(15, &gen_usize(1, 5000), |&seed| {
        let (cands, mut pred, cfg) = candidates_from_seed(seed as u64, 10);
        let out = schedule(cands, &mut pred, &cfg);
        let mut seen = std::collections::HashSet::new();
        for (g, _) in &out.groups {
            for gpu in &g.alloc.gpus {
                if !seen.insert(*gpu) {
                    return false; // same GPU in two groups
                }
            }
        }
        true
    });
}

#[test]
fn urgent_jobs_get_seeded_first() {
    // a job near its slowdown bound must not end up in a *worse* group
    // than it started in: schedule, then verify its slowdown <= Δ^max
    let (mut cands, mut pred, cfg) = candidates_from_seed(77, 8);
    if cands.is_empty() {
        return;
    }
    cands[0].urgency = 100.0; // critically urgent
    let id = cands[0].job.id;
    let out = schedule(cands, &mut pred, &cfg);
    let (g, perf) = out
        .groups
        .iter()
        .find(|(g, _)| g.jobs.iter().any(|j| j.id == id))
        .unwrap();
    let j = g.jobs.iter().find(|j| j.id == id).unwrap();
    let sd = perf
        .slowdowns
        .iter()
        .find(|(jid, _)| *jid == id)
        .unwrap()
        .1;
    assert!(sd <= j.max_slowdown + 1e-9, "urgent job slowed {sd}");
}

#[test]
fn deterministic_given_same_input() {
    let (cands, mut pred, cfg) = candidates_from_seed(99, 10);
    let out1 = schedule(cands.clone(), &mut pred, &cfg);
    let mut pred2 = Predictor::new(
        pred.spec().clone(),
        PlanOptions::default(),
    );
    let out2 = schedule(cands, &mut pred2, &cfg);
    let sig = |o: &tlora::scheduler::ScheduleOutcome| -> Vec<Vec<u64>> {
        let mut v: Vec<Vec<u64>> = o
            .groups
            .iter()
            .map(|(g, _)| {
                let mut ids: Vec<u64> =
                    g.jobs.iter().map(|j| j.id).collect();
                ids.sort_unstable();
                ids
            })
            .collect();
        v.sort();
        v
    };
    assert_eq!(sig(&out1), sig(&out2));
}
