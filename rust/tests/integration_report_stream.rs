//! Streaming-report differential: the O(1)-memory streaming writer
//! ([`StreamReport`]) must be byte-identical to the legacy
//! collect-then-emit path on the *golden grid* (the same pinned
//! faulted sweep `integration_golden.rs` blesses), at thread counts 1
//! and 8, across every output form — canonical JSON, CSV, and the
//! rendered summary table. Failures are localized with the lazy
//! byte-range differ (`json::diff`) so a regression names the first
//! diverging path instead of dumping two multi-kilobyte strings.

use tlora::config::Policy;
use tlora::sweep::{
    aggregate, run, run_streaming, sweep_table, to_csv,
    to_json_canonical, Spool, StreamReport, SweepGrid,
};
use tlora::util::json;

/// Keep in sync with `golden_grid()` in `integration_golden.rs`.
fn golden_grid() -> SweepGrid {
    let mut g = SweepGrid::default();
    g.policies = vec![Policy::TLora, Policy::Megatron];
    g.n_jobs = vec![10];
    g.gpus = vec![16];
    g.rate_scales = vec![2.0];
    g.months = vec![1];
    g.mtbfs = vec![0.0, 900.0];
    g.seeds = vec![7, 8];
    g
}

/// Panic with the first diverging JSON path when canonical streams
/// differ; plain `assert_eq!` on multi-KB strings buries it.
fn assert_canonical_eq(expect: &str, got: &str, ctx: &str) {
    if expect != got {
        match json::diff(expect, got) {
            Some(d) => panic!("{ctx}: first divergence at {d}"),
            None => panic!(
                "{ctx}: bytes differ but the lazy differ found no \
                 semantic divergence — formatting drift between \
                 writers"
            ),
        }
    }
}

/// Run the streaming report with in-memory sinks at `threads`.
fn stream_outputs(
    grid: &SweepGrid,
    threads: usize,
) -> (String, String, Vec<tlora::sweep::CellSummary>) {
    let mut jbuf: Vec<u8> = Vec::new();
    let mut cbuf: Vec<u8> = Vec::new();
    let mut report = StreamReport::new(grid, false)
        .with_json(&mut jbuf, Spool::memory())
        .with_csv(&mut cbuf);
    let stats = run_streaming(grid, threads, &mut |pr| {
        report.point(&pr).map_err(|e| format!("emission: {e}"))
    })
    .unwrap();
    let cells = report.finish(stats.n_threads, stats.wall_s).unwrap();
    (
        String::from_utf8(jbuf).unwrap(),
        String::from_utf8(cbuf).unwrap(),
        cells,
    )
}

#[test]
fn streaming_report_matches_legacy_on_golden_grid() {
    let g = golden_grid();
    let legacy_run = run(&g, 8).unwrap();
    let legacy_json = to_json_canonical(&legacy_run).to_pretty();
    let legacy_csv = to_csv(&legacy_run);
    let legacy_table =
        sweep_table("t", &aggregate(&legacy_run)).render();

    for threads in [1usize, 8] {
        let (sj, sc, cells) = stream_outputs(&g, threads);
        assert_canonical_eq(
            &legacy_json,
            &sj,
            &format!(
                "streaming canonical JSON (threads {threads}) vs \
                 legacy writer"
            ),
        );
        assert_eq!(
            legacy_csv, sc,
            "streaming CSV diverged from legacy at threads {threads}"
        );
        assert_eq!(
            legacy_table,
            sweep_table("t", &cells).render(),
            "streaming summary table diverged at threads {threads}"
        );
    }
}

#[test]
fn duplicate_axis_values_are_rejected_not_misaggregated() {
    // Duplicate axis values make a cell key reappear after its
    // accumulator closed; the streaming writer must refuse (pointing
    // at --legacy-report) rather than emit a second partial cell.
    let mut g = SweepGrid::default();
    g.policies = vec![Policy::TLora];
    g.n_jobs = vec![10];
    g.gpus = vec![16];
    g.rate_scales = vec![2.0];
    g.months = vec![1];
    g.mtbfs = vec![0.0, 900.0, 0.0];
    g.seeds = vec![7];
    let points = {
        let run = run(&g, 1).unwrap();
        run.points
    };
    let mut rep = StreamReport::new(&g, false);
    rep.point(&points[0]).unwrap();
    rep.point(&points[1]).unwrap();
    let err = rep.point(&points[2]).unwrap_err().to_string();
    assert!(
        err.contains("non-adjacently") && err.contains("legacy"),
        "duplicate-cell error should direct to the legacy report: \
         {err}"
    );
}
