//! Integration: the PJRT runtime against the real AOT artifacts.
//!
//! These tests need `make artifacts` to have run; they skip (pass
//! trivially with a note) when artifacts/ is absent so `cargo test`
//! works on a fresh checkout.

use std::path::PathBuf;

use tlora::runtime::{Runtime, Trainer};
use tlora::train::data::SyntheticCorpus;

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("artifacts/ missing — skipping runtime integration");
        None
    }
}

#[test]
fn manifest_matches_model_contract() {
    let Some(dir) = artifacts() else { return };
    let m = tlora::runtime::Manifest::load(&dir).unwrap();
    for v in &m.variants {
        assert_eq!(v.n_backbone, 10, "{}", v.name);
        assert_eq!(v.n_lora, 4, "{}", v.name);
        assert_eq!(v.step.inputs.len(), v.n_state() + 2, "{}", v.name);
        assert_eq!(v.step.outputs.len(), 3 * v.n_lora + 3, "{}", v.name);
        // tokens input shape == (total_batch, seq_len)
        let tok = &v.step.inputs[v.n_state()];
        assert_eq!(
            tok.shape,
            vec![v.config.total_batch(), v.config.seq_len],
            "{}",
            v.name
        );
    }
    // every expected variant present
    for name in ["tiny", "tiny_unfused", "small", "med", "e2e100m"] {
        assert!(m.variant(name).is_some(), "missing variant {name}");
    }
    assert!(m.kmicro.len() >= 6);
}

#[test]
fn init_is_seed_deterministic() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let t1 = Trainer::new(&rt, "tiny", 7).unwrap();
    let t2 = Trainer::new(&rt, "tiny", 7).unwrap();
    let t3 = Trainer::new(&rt, "tiny", 8).unwrap();
    let (a, b, c) = (
        t1.lora_state().unwrap(),
        t2.lora_state().unwrap(),
        t3.lora_state().unwrap(),
    );
    assert_eq!(a, b, "same seed must give identical init");
    assert_ne!(a, c, "different seed must change init");
    // LoRA B matrices init to zero (standard LoRA init): indices 1, 3
    assert!(a[1].iter().all(|&x| x == 0.0));
    assert!(a[3].iter().all(|&x| x == 0.0));
}

#[test]
fn training_reduces_loss_and_updates_only_active_adapters() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let mut tr = Trainer::new(&rt, "tiny", 0).unwrap();
    let cfg = tr.variant().config.clone();
    let mut corpus =
        SyntheticCorpus::new(cfg.vocab, cfg.seq_len, cfg.num_adapters, 5);

    let before = tr.lora_state().unwrap();
    let mut first = f32::NAN;
    let mut losses = vec![];
    for i in 0..30 {
        let (tokens, mut ids) = corpus.fused_batch(&cfg.batch_sizes);
        // retire adapter 3 for the entire run (mask its sequences)
        for id in ids.iter_mut() {
            if *id == 3 {
                *id = -1;
            }
        }
        let s = tr.step(&tokens, &ids).unwrap();
        if i == 0 {
            first = s.loss;
        }
        losses.push(s.loss);
        assert!(s.loss.is_finite());
        assert_eq!(s.per_adapter_loss.len(), cfg.num_adapters);
    }
    let last = *losses.last().unwrap();
    assert!(last < first, "loss did not decrease: {first} -> {last}");

    let after = tr.lora_state().unwrap();
    // a_q layout: (L, K, D, R) — adapter 3's slice must be untouched
    let v = tr.variant().clone();
    let (l, k, d, r) = (
        v.config.n_layers,
        v.config.num_adapters,
        v.config.d_model,
        v.config.r_max,
    );
    let idx = |layer: usize, adapter: usize| -> std::ops::Range<usize> {
        let per_adapter = d * r;
        let per_layer = k * per_adapter;
        let start = layer * per_layer + adapter * per_adapter;
        start..start + per_adapter
    };
    let mut changed_active = false;
    for layer in 0..l {
        let range3 = idx(layer, 3);
        assert_eq!(
            &before[0][range3.clone()],
            &after[0][range3],
            "masked adapter 3 was updated"
        );
        let range0 = idx(layer, 0);
        if before[0][range0.clone()] != after[0][range0] {
            changed_active = true;
        }
    }
    assert!(changed_active, "active adapters never updated");
}

#[test]
fn fused_and_unfused_variants_agree() {
    // the Fig. 7 lossless claim at the artifact level: same seed, same
    // data => same losses through either kernel path
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let mut a = Trainer::new(&rt, "tiny", 3).unwrap();
    let mut b = Trainer::new_with_init_from(&rt, "tiny_unfused", "tiny", 3)
        .unwrap();
    let cfg = a.variant().config.clone();
    let mut corpus =
        SyntheticCorpus::new(cfg.vocab, cfg.seq_len, cfg.num_adapters, 9);
    for _ in 0..5 {
        let (tokens, ids) = corpus.fused_batch(&cfg.batch_sizes);
        let sa = a.step(&tokens, &ids).unwrap();
        let sb = b.step(&tokens, &ids).unwrap();
        assert!(
            (sa.loss - sb.loss).abs() < 1e-4,
            "fused {} vs unfused {}",
            sa.loss,
            sb.loss
        );
    }
}

#[test]
fn nano_variant_matches_full_batch_step() {
    // the §3.3 claim that nano-batching never changes numerics, checked
    // on the real artifacts: tiny_nano2 == tiny for round-robin batches
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    if rt.manifest.variant("tiny_nano2").is_none() {
        eprintln!("tiny_nano2 missing — skip");
        return;
    }
    let mut full = Trainer::new(&rt, "tiny", 11).unwrap();
    let mut nano =
        Trainer::new_with_init_from(&rt, "tiny_nano2", "tiny", 11).unwrap();
    let cfg = full.variant().config.clone();
    let mut corpus =
        SyntheticCorpus::new(cfg.vocab, cfg.seq_len, cfg.num_adapters, 2);
    for _ in 0..3 {
        let (tokens, ids) = corpus.fused_batch(&cfg.batch_sizes);
        let sf = full.step(&tokens, &ids).unwrap();
        let sn = nano.step(&tokens, &ids).unwrap();
        assert!(
            (sf.loss - sn.loss).abs() < 1e-4,
            "full {} vs nano {}",
            sf.loss,
            sn.loss
        );
    }
}

#[test]
fn checkpoint_roundtrip_resumes_identically() {
    // save -> restore must reproduce the exact same next-step loss as
    // the uninterrupted run
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let mut a = Trainer::new(&rt, "tiny", 21).unwrap();
    let cfg = a.variant().config.clone();
    let mut corpus =
        SyntheticCorpus::new(cfg.vocab, cfg.seq_len, cfg.num_adapters, 4);
    for _ in 0..5 {
        let (tokens, ids) = corpus.fused_batch(&cfg.batch_sizes);
        a.step(&tokens, &ids).unwrap();
    }
    let ck = tlora::runtime::Checkpoint::capture(&a, 21).unwrap();
    let path = std::env::temp_dir().join("tlora_it_resume.ckpt");
    ck.save(&path).unwrap();
    let mut b = tlora::runtime::Checkpoint::load(&path)
        .unwrap()
        .restore(&rt)
        .unwrap();
    assert_eq!(b.steps_done, 5);
    std::fs::remove_file(&path).ok();
    // both trainers must now agree step-for-step
    for _ in 0..3 {
        let (tokens, ids) = corpus.fused_batch(&cfg.batch_sizes);
        let sa = a.step(&tokens, &ids).unwrap();
        let sb = b.step(&tokens, &ids).unwrap();
        assert!(
            (sa.loss - sb.loss).abs() < 1e-6,
            "diverged after resume: {} vs {}",
            sa.loss,
            sb.loss
        );
    }
}
