//! Golden-trace regression: a small pinned *faulted* sweep whose full
//! canonical JSON output is committed as a diffable fixture, turning
//! the determinism contract into an artifact a code review can read.
//!
//! The pinned grid (keep in sync with `golden_grid()` below; the
//! straggler axis stays at its 0 default, so the fixture doubles as
//! the straggler-free differential reference —
//! `straggler_machinery_is_byte_free_when_disabled` — and the
//! hardware axis stays at its homogeneous-reference default, so it
//! also pins that the tier/pipeline machinery, the checkpoint-cadence
//! defaults (`ckpt_interval_steps = 1`, `ckpt_write_s = 0`), and the
//! gated tier-utilization report columns are byte-free until a mixed
//! fleet is requested):
//!
//! ```text
//! tlora sweep --policies tlora,megatron --n-jobs 10 --gpus 16 \
//!             --rate-scales 2 --months 1 --mtbfs 0,900 --seeds 7,8 \
//!             --threads 8 --canonical \
//!             --out-json tests/fixtures/golden_sweep.json
//! ```
//!
//! Regenerate the fixture with exactly that invocation (from `rust/`)
//! after any *intended* change to simulator numerics, then commit the
//! diff. The canonical JSON form strips wall-clock and thread-count
//! fields, so the bytes are a pure function of the grid.
//!
//! Bless protocol: when the fixture file is missing or still holds the
//! `UNBLESSED` sentinel, the test writes the freshly computed output
//! into it and passes (first bootstrap on a machine with a toolchain);
//! once a real fixture is committed, any byte difference fails.

use tlora::config::Policy;
use tlora::sweep::{run, to_json_canonical, SweepGrid};
use tlora::util::json;

/// Panic with the first diverging JSON path (via the lazy byte-range
/// differ) instead of dumping two multi-kilobyte canonical strings.
fn assert_canonical_eq(expect: &str, got: &str, ctx: &str) {
    if expect != got {
        match json::diff(expect, got) {
            Some(d) => panic!("{ctx}; first divergence at {d}"),
            None => panic!(
                "{ctx}; bytes differ but the lazy differ found no \
                 semantic divergence (formatting drift)"
            ),
        }
    }
}

fn golden_grid() -> SweepGrid {
    let mut g = SweepGrid::default();
    g.policies = vec![Policy::TLora, Policy::Megatron];
    g.n_jobs = vec![10];
    g.gpus = vec![16];
    g.rate_scales = vec![2.0];
    g.months = vec![1];
    g.mtbfs = vec![0.0, 900.0];
    g.seeds = vec![7, 8];
    g
}

fn fixture_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/golden_sweep.json")
}

#[test]
fn golden_faulted_sweep_is_bit_identical_across_threads_and_runs() {
    let g = golden_grid();
    let serial = run(&g, 1).unwrap();
    let parallel = run(&g, 8).unwrap();
    let canon = to_json_canonical(&serial).to_pretty();
    let canon_par = to_json_canonical(&parallel).to_pretty();
    assert_canonical_eq(
        &canon,
        &canon_par,
        "canonical sweep JSON differs between --threads 1 and 8",
    );

    // structural pins on the output itself (hold whether or not the
    // fixture is blessed yet)
    let parsed = tlora::util::json::parse(&canon).unwrap();
    let points = parsed.get("points").unwrap().as_arr().unwrap();
    assert_eq!(points.len(), g.len());
    assert_eq!(
        points[0].get("label").unwrap().as_str().unwrap(),
        "tlora/j10/g16/r2x/m1/f0/d0/s7"
    );
    let mut churned = 0u64;
    for p in points {
        let completed =
            p.get("completed").unwrap().as_usize().unwrap();
        let incomplete =
            p.get("incomplete").unwrap().as_usize().unwrap();
        assert_eq!(completed + incomplete, 10, "job conservation");
        assert_eq!(incomplete, 0, "golden scenario truncated work");
        let mtbf = p.get("mtbf_s").unwrap().as_f64().unwrap();
        let failures =
            p.get("node_failures").unwrap().as_i64().unwrap() as u64;
        if mtbf == 0.0 {
            assert_eq!(failures, 0, "churn in a fault-free cell");
        } else {
            churned += failures;
        }
        // the golden grid is straggler-free: its degraded-node
        // columns must be exactly quiescent
        assert_eq!(
            p.get("node_degrades").unwrap().as_i64().unwrap(),
            0,
            "straggler episode in a straggler-free golden cell"
        );
        assert_eq!(
            p.get("migrations").unwrap().as_i64().unwrap(),
            0
        );
    }
    assert!(churned > 0, "no faulted cell saw a single failure");

    // fixture comparison / first-run bless
    let path = fixture_path();
    let blessed = std::fs::read_to_string(&path)
        .ok()
        .filter(|s| !s.contains("UNBLESSED"));
    match blessed {
        Some(expect) => assert_canonical_eq(
            &expect,
            &canon,
            "sweep output diverged from the committed golden \
             fixture; if the numeric change is intended, regenerate \
             it (see the header of this file) and commit the diff",
        ),
        None => {
            std::fs::create_dir_all(path.parent().unwrap()).unwrap();
            std::fs::write(&path, &canon).unwrap();
            eprintln!(
                "golden fixture blessed at {}; commit it to pin the \
                 determinism contract across checkouts",
                path.display()
            );
        }
    }
}

#[test]
fn straggler_machinery_is_byte_free_when_disabled() {
    // differential regression for the straggler subsystem: on a
    // straggler-free grid (the golden grid — MTBS 0), every piece of
    // the new machinery must be a no-op down to the byte. Three
    // configurations that differ only in dormant straggler knobs must
    // produce identical canonical JSON:
    //   1. the golden grid as-is (stragglers axis defaulted to 0),
    //   2. the same grid with the axis spelled out explicitly as 0,
    //   3. the same grid with detection force-disabled in the base
    //      config (no estimator could have existed either way — this
    //      pins that the detect flag alone never perturbs dynamics).
    // Together with the fixture comparison above, this proves the new
    // event kinds, per-node speed bookkeeping (step_time = base/1.0),
    // and avoid-aware admission are zero-cost when disabled.
    let g = golden_grid();
    let base = to_json_canonical(&run(&g, 2).unwrap()).to_pretty();

    let mut explicit = golden_grid();
    explicit.stragglers = vec![0.0];
    let explicit_out =
        to_json_canonical(&run(&explicit, 2).unwrap()).to_pretty();
    assert_canonical_eq(
        &base,
        &explicit_out,
        "explicit --stragglers 0 diverged from the default axis",
    );

    let mut oblivious = golden_grid();
    oblivious.base.stragglers.detect = false;
    let oblivious_out =
        to_json_canonical(&run(&oblivious, 2).unwrap()).to_pretty();
    assert_canonical_eq(
        &base,
        &oblivious_out,
        "stragglers.detect changed a straggler-free run",
    );
}
