//! Golden-trace regression: a small pinned *faulted* sweep whose full
//! canonical JSON output is committed as a diffable fixture, turning
//! the determinism contract into an artifact a code review can read.
//!
//! The pinned grid (keep in sync with `golden_grid()` below):
//!
//! ```text
//! tlora sweep --policies tlora,megatron --n-jobs 10 --gpus 16 \
//!             --rate-scales 2 --months 1 --mtbfs 0,900 --seeds 7,8 \
//!             --threads 8 --canonical \
//!             --out-json tests/fixtures/golden_sweep.json
//! ```
//!
//! Regenerate the fixture with exactly that invocation (from `rust/`)
//! after any *intended* change to simulator numerics, then commit the
//! diff. The canonical JSON form strips wall-clock and thread-count
//! fields, so the bytes are a pure function of the grid.
//!
//! Bless protocol: when the fixture file is missing or still holds the
//! `UNBLESSED` sentinel, the test writes the freshly computed output
//! into it and passes (first bootstrap on a machine with a toolchain);
//! once a real fixture is committed, any byte difference fails.

use tlora::config::Policy;
use tlora::sweep::{run, to_json_canonical, SweepGrid};

fn golden_grid() -> SweepGrid {
    let mut g = SweepGrid::default();
    g.policies = vec![Policy::TLora, Policy::Megatron];
    g.n_jobs = vec![10];
    g.gpus = vec![16];
    g.rate_scales = vec![2.0];
    g.months = vec![1];
    g.mtbfs = vec![0.0, 900.0];
    g.seeds = vec![7, 8];
    g
}

fn fixture_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/golden_sweep.json")
}

#[test]
fn golden_faulted_sweep_is_bit_identical_across_threads_and_runs() {
    let g = golden_grid();
    let serial = run(&g, 1).unwrap();
    let parallel = run(&g, 8).unwrap();
    let canon = to_json_canonical(&serial).to_pretty();
    let canon_par = to_json_canonical(&parallel).to_pretty();
    assert_eq!(
        canon, canon_par,
        "canonical sweep JSON differs between --threads 1 and 8"
    );

    // structural pins on the output itself (hold whether or not the
    // fixture is blessed yet)
    let parsed = tlora::util::json::parse(&canon).unwrap();
    let points = parsed.get("points").unwrap().as_arr().unwrap();
    assert_eq!(points.len(), g.len());
    assert_eq!(
        points[0].get("label").unwrap().as_str().unwrap(),
        "tlora/j10/g16/r2x/m1/f0/s7"
    );
    let mut churned = 0u64;
    for p in points {
        let completed =
            p.get("completed").unwrap().as_usize().unwrap();
        let incomplete =
            p.get("incomplete").unwrap().as_usize().unwrap();
        assert_eq!(completed + incomplete, 10, "job conservation");
        assert_eq!(incomplete, 0, "golden scenario truncated work");
        let mtbf = p.get("mtbf_s").unwrap().as_f64().unwrap();
        let failures =
            p.get("node_failures").unwrap().as_i64().unwrap() as u64;
        if mtbf == 0.0 {
            assert_eq!(failures, 0, "churn in a fault-free cell");
        } else {
            churned += failures;
        }
    }
    assert!(churned > 0, "no faulted cell saw a single failure");

    // fixture comparison / first-run bless
    let path = fixture_path();
    let blessed = std::fs::read_to_string(&path)
        .ok()
        .filter(|s| !s.contains("UNBLESSED"));
    match blessed {
        Some(expect) => assert_eq!(
            canon, expect,
            "sweep output diverged from the committed golden \
             fixture; if the numeric change is intended, regenerate \
             it (see the header of this file) and commit the diff"
        ),
        None => {
            std::fs::create_dir_all(path.parent().unwrap()).unwrap();
            std::fs::write(&path, &canon).unwrap();
            eprintln!(
                "golden fixture blessed at {}; commit it to pin the \
                 determinism contract across checkouts",
                path.display()
            );
        }
    }
}
