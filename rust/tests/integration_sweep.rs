//! Cross-layer determinism: the sweep engine must produce bit-identical
//! results regardless of worker-thread count and across consecutive
//! runs, and each sweep cell must match a direct `sim::simulate` call
//! with the same configuration. Together these pin the whole stack —
//! trace generation, scheduling, planning, the AIMD controller, and the
//! parallel executor — to "output is a pure function of (grid, seed)".

use tlora::config::Policy;
use tlora::sim::{simulate, SimResult};
use tlora::sweep::{aggregate, run, to_csv, to_json, SweepGrid};

fn small_grid() -> SweepGrid {
    let mut g = SweepGrid::default();
    g.policies = vec![Policy::TLora, Policy::Megatron];
    g.n_jobs = vec![10];
    g.gpus = vec![16];
    g.rate_scales = vec![1.0, 2.0];
    g.months = vec![1];
    g.seeds = vec![7, 8];
    g
}

/// Bit-identical comparison of every deterministic SimResult field
/// (wall-clock diagnostics live outside SimResult and are exempt).
fn assert_bit_identical(a: &SimResult, b: &SimResult, label: &str) {
    assert_eq!(a.jct, b.jct, "{label}: jct");
    assert_eq!(a.sched_rounds, b.sched_rounds, "{label}: rounds");
    assert_eq!(a.events, b.events, "{label}: events");
    assert_eq!(
        a.incomplete_jobs, b.incomplete_jobs,
        "{label}: incomplete"
    );
    assert_eq!(
        a.scheduler_probes, b.scheduler_probes,
        "{label}: probes"
    );
    assert!(
        a.mean_jct == b.mean_jct && a.p99_jct == b.p99_jct,
        "{label}: jct summary"
    );
    assert!(
        a.avg_throughput == b.avg_throughput,
        "{label}: throughput {} vs {}",
        a.avg_throughput,
        b.avg_throughput
    );
    assert!(a.avg_gpu_util == b.avg_gpu_util, "{label}: util");
    assert!(
        a.avg_throughput_full == b.avg_throughput_full
            && a.avg_gpu_util_full == b.avg_gpu_util_full,
        "{label}: full-run averages"
    );
    assert!(a.makespan == b.makespan, "{label}: makespan");
    assert!(a.mean_slowdown == b.mean_slowdown, "{label}: slowdown");
    assert_eq!(
        a.throughput_timeline, b.throughput_timeline,
        "{label}: thr timeline"
    );
    assert_eq!(
        a.util_timeline, b.util_timeline,
        "{label}: util timeline"
    );
    assert_eq!(
        a.grouping_ratio, b.grouping_ratio,
        "{label}: grouping ratio"
    );
    assert_eq!(a.node_failures, b.node_failures, "{label}: failures");
    assert_eq!(a.preemptions, b.preemptions, "{label}: preemptions");
    assert_eq!(a.restarts, b.restarts, "{label}: restarts");
    assert!(
        a.lost_step_time_s == b.lost_step_time_s
            && a.restore_delay_s == b.restore_delay_s,
        "{label}: churn accounting"
    );
    assert!(a.goodput == b.goodput, "{label}: goodput");
    assert!(
        a.slo_attainment == b.slo_attainment,
        "{label}: slo attainment"
    );
    assert_eq!(a.node_degrades, b.node_degrades, "{label}: degrades");
    assert_eq!(a.migrations, b.migrations, "{label}: migrations");
    assert!(
        a.degraded_node_time_s == b.degraded_node_time_s
            && a.straggler_slowdown == b.straggler_slowdown,
        "{label}: straggler accounting"
    );
    assert_eq!(a.tier_util, b.tier_util, "{label}: tier util");
    assert!(
        a.rack_span_mean == b.rack_span_mean
            && a.rack_span_max == b.rack_span_max,
        "{label}: rack span"
    );
}

#[test]
fn n_threads_matches_single_thread_bitwise() {
    let g = small_grid();
    let serial = run(&g, 1).unwrap();
    let parallel = run(&g, 4).unwrap();
    assert_eq!(serial.points.len(), g.len());
    assert_eq!(parallel.points.len(), g.len());
    for (a, b) in serial.points.iter().zip(&parallel.points) {
        assert_eq!(a.point, b.point, "cell order must be identical");
        assert_bit_identical(&a.result, &b.result, &a.point.label());
    }
}

#[test]
fn consecutive_parallel_runs_bitwise_identical() {
    let g = small_grid();
    let first = run(&g, 3).unwrap();
    let second = run(&g, 3).unwrap();
    for (a, b) in first.points.iter().zip(&second.points) {
        assert_eq!(a.point, b.point);
        assert_bit_identical(&a.result, &b.result, &a.point.label());
    }
}

#[test]
fn faulted_grid_is_bit_identical_across_thread_counts() {
    // the MTBF axis rides the same determinism contract: per-node
    // fault streams are pure functions of (seed, node), so a faulted
    // sweep must not depend on worker count either
    let mut g = small_grid();
    g.rate_scales = vec![2.0];
    g.mtbfs = vec![0.0, 900.0];
    let serial = run(&g, 1).unwrap();
    let parallel = run(&g, 4).unwrap();
    let mut churn = 0u64;
    for (a, b) in serial.points.iter().zip(&parallel.points) {
        assert_eq!(a.point, b.point);
        assert_bit_identical(&a.result, &b.result, &a.point.label());
        if a.point.mtbf_s == 0.0 {
            assert_eq!(a.result.node_failures, 0, "{}", a.point.label());
        } else {
            churn += a.result.node_failures;
        }
    }
    assert!(churn > 0, "faulted cells produced no churn");
    // each faulted cell equals a direct simulate of its config
    for p in serial.points.iter().filter(|p| p.point.mtbf_s > 0.0) {
        let direct = simulate(&p.point.config(&g.base));
        assert_bit_identical(&p.result, &direct, &p.point.label());
    }
}

#[test]
fn straggler_grid_is_bit_identical_across_thread_counts() {
    // the straggler axis rides the same determinism contract: per-node
    // degrade/restore streams are pure functions of (seed, node), and
    // the detection estimator is a pure function of the event stream,
    // so a degraded sweep must not depend on worker count either
    let mut g = small_grid();
    g.rate_scales = vec![2.0];
    g.stragglers = vec![0.0, 600.0];
    let serial = run(&g, 1).unwrap();
    let parallel = run(&g, 4).unwrap();
    let mut degrades = 0u64;
    for (a, b) in serial.points.iter().zip(&parallel.points) {
        assert_eq!(a.point, b.point);
        assert_bit_identical(&a.result, &b.result, &a.point.label());
        if a.point.straggler_mtbs_s == 0.0 {
            assert_eq!(
                a.result.node_degrades,
                0,
                "{}",
                a.point.label()
            );
            assert_eq!(a.result.degraded_node_time_s, 0.0);
            assert_eq!(a.result.straggler_slowdown, 1.0);
            assert_eq!(a.result.migrations, 0);
        } else {
            degrades += a.result.node_degrades;
            assert!(
                a.result.straggler_slowdown >= 1.0,
                "{}",
                a.point.label()
            );
        }
    }
    assert!(degrades > 0, "straggler cells produced no episodes");
    // each degraded cell equals a direct simulate of its config
    for p in serial
        .points
        .iter()
        .filter(|p| p.point.straggler_mtbs_s > 0.0)
    {
        let direct = simulate(&p.point.config(&g.base));
        assert_bit_identical(&p.result, &direct, &p.point.label());
    }
}

#[test]
fn explicit_reference_tier_is_bitwise_free() {
    // "a100" is the reference generation: all-1.0 multipliers are
    // exact float no-ops (x*1.0 == x bitwise) and the plan-cache key
    // canonicalizes all-reference tier patterns to the homogeneous
    // form, so a sweep that names the reference tier explicitly must
    // replay the default fleet bit-for-bit — the tier machinery is
    // free until a genuinely mixed fleet is requested
    let g = small_grid();
    let mut gm = small_grid();
    gm.hardware_mixes = vec!["a100".into()];
    let a = run(&g, 2).unwrap();
    let b = run(&gm, 2).unwrap();
    assert_eq!(a.points.len(), b.points.len());
    for (x, y) in a.points.iter().zip(&b.points) {
        // only the cell key grows the explicit /h component
        assert_eq!(
            format!("{}/ha100", x.point.cell_key()),
            y.point.cell_key()
        );
        assert_bit_identical(&x.result, &y.result, &y.point.label());
        // uniform-reference fleets never build tier accumulators
        assert!(y.result.tier_util.is_empty());
    }
}

#[test]
fn mixed_tier_grid_is_bit_identical_across_thread_counts() {
    // the hardware-mix axis rides the same determinism contract:
    // tiers are a static property priced into plans, so a mixed-fleet
    // sweep must not depend on worker count, and its canonical JSON
    // must diff byte-exactly between 1 and 8 threads
    let mut g = small_grid();
    g.rate_scales = vec![2.0];
    g.hardware_mixes = vec!["".into(), "a100:v100".into()];
    let serial = run(&g, 1).unwrap();
    let parallel = run(&g, 8).unwrap();
    assert_eq!(serial.points.len(), g.len());
    for (a, b) in serial.points.iter().zip(&parallel.points) {
        assert_eq!(a.point, b.point);
        assert_bit_identical(&a.result, &b.result, &a.point.label());
        if a.point.hardware_mix.is_empty() {
            assert!(
                a.result.tier_util.is_empty(),
                "{}",
                a.point.label()
            );
        } else {
            assert_eq!(
                a.result.tier_util.len(),
                2,
                "{}",
                a.point.label()
            );
            for (name, u) in &a.result.tier_util {
                assert!(
                    (0.0..=1.0).contains(u),
                    "{}: {name} util {u}",
                    a.point.label()
                );
            }
        }
    }
    let canon =
        tlora::sweep::to_json_canonical(&serial).to_pretty();
    let canon_par =
        tlora::sweep::to_json_canonical(&parallel).to_pretty();
    if canon != canon_par {
        panic!(
            "mixed-tier canonical JSON differs across thread counts; \
             first divergence at {}",
            tlora::util::json::diff(&canon, &canon_par)
                .map(|d| d.to_string())
                .unwrap_or_else(|| "formatting drift".into())
        );
    }
    // each mixed cell equals a direct simulate of its config
    for p in serial
        .points
        .iter()
        .filter(|p| !p.point.hardware_mix.is_empty())
    {
        let direct = simulate(&p.point.config(&g.base));
        assert_bit_identical(&p.result, &direct, &p.point.label());
    }
}

#[test]
fn topology_grid_is_bit_identical_across_thread_counts() {
    // the topology axis rides the same determinism contract: the
    // rack/region tree is a static property priced into plans and
    // placement, so a non-flat sweep must not depend on worker count,
    // and its canonical JSON must diff byte-exactly between 1 and 8
    // threads (divergences localized by the lazy json differ)
    let mut g = small_grid();
    g.rate_scales = vec![2.0];
    g.gpus = vec![32];
    g.topologies = vec!["".into(), "racks=4:rack_bw=0.5".into()];
    let serial = run(&g, 1).unwrap();
    let parallel = run(&g, 8).unwrap();
    assert_eq!(serial.points.len(), g.len());
    for (a, b) in serial.points.iter().zip(&parallel.points) {
        assert_eq!(a.point, b.point);
        assert_bit_identical(&a.result, &b.result, &a.point.label());
        if a.point.topology.is_empty() {
            // flat cells never construct the rack-span tracker
            assert_eq!(
                a.result.rack_span_mean,
                0.0,
                "{}",
                a.point.label()
            );
            assert_eq!(a.result.rack_span_max, 0);
        } else {
            assert!(
                a.result.rack_span_mean >= 1.0,
                "{}: no gang ever observed",
                a.point.label()
            );
            assert!(a.result.rack_span_max >= 1);
        }
    }
    let canon =
        tlora::sweep::to_json_canonical(&serial).to_pretty();
    let canon_par =
        tlora::sweep::to_json_canonical(&parallel).to_pretty();
    if canon != canon_par {
        panic!(
            "topology canonical JSON differs across thread counts; \
             first divergence at {}",
            tlora::util::json::diff(&canon, &canon_par)
                .map(|d| d.to_string())
                .unwrap_or_else(|| "formatting drift".into())
        );
    }
    // each non-flat cell equals a direct simulate of its config
    for p in serial
        .points
        .iter()
        .filter(|p| !p.point.topology.is_empty())
    {
        let direct = simulate(&p.point.config(&g.base));
        assert_bit_identical(&p.result, &direct, &p.point.label());
    }
}

#[test]
fn sweep_cell_matches_direct_simulate() {
    let g = small_grid();
    let swept = run(&g, 2).unwrap();
    for p in &swept.points {
        let direct = simulate(&p.point.config(&g.base));
        assert_bit_identical(&p.result, &direct, &p.point.label());
    }
}

#[test]
fn aggregation_pools_exactly_the_seed_replicas() {
    let g = small_grid();
    let swept = run(&g, 2).unwrap();
    let cells = aggregate(&swept);
    // 2 policies x 2 rate scales = 4 scenarios, each with 2 seeds
    assert_eq!(cells.len(), 4);
    for c in &cells {
        assert_eq!(c.n_seeds, 2, "{}", c.key);
        assert!(c.throughput.0 > 0.0);
        assert!(c.throughput.1 >= 0.0);
        assert!(c.mean_jct.0 > 0.0);
    }
}

#[test]
fn reports_are_complete_and_parsable() {
    let g = small_grid();
    let swept = run(&g, 2).unwrap();
    let csv = to_csv(&swept);
    assert_eq!(csv.lines().count(), swept.points.len() + 1);
    let parsed =
        tlora::util::json::parse(&to_json(&swept).to_string()).unwrap();
    assert_eq!(
        parsed.get("points").unwrap().as_arr().unwrap().len(),
        swept.points.len()
    );
    assert_eq!(parsed.get("cells").unwrap().as_arr().unwrap().len(), 4);
    // every job completed in every cell
    for pt in parsed.get("points").unwrap().as_arr().unwrap() {
        assert_eq!(
            pt.get("completed").unwrap().as_usize().unwrap(),
            10,
            "incomplete cell {:?}",
            pt.get("label")
        );
    }
}
