//! Property tests (via the in-crate `util::prop` framework) for the
//! Adapter Scheduler's §3.4 invariants, checked independently of the
//! scheduler's own bookkeeping:
//!
//! 1. capacity — no scheduling round hands out more GPUs than the
//!    cluster has, never shares a GPU between groups, and never invents
//!    a GPU outside the cluster topology;
//! 2. liveness — every submitted job is scheduled into exactly one
//!    group each round, and at the simulator level every job eventually
//!    completes;
//! 3. bounded slowdown — grouping never raises a member's modeled
//!    per-step time above its solo baseline by more than its Δ^max,
//!    recomputed here from the predictor's isolated step time rather
//!    than trusting the scheduler's recorded slowdowns.

use std::collections::HashSet;

use tlora::cluster::{Allocation, Allocator, ClusterSpec};
use tlora::config::{ExperimentConfig, Policy, SchedulerConfig};
use tlora::planner::PlanOptions;
use tlora::scheduler::predictor::Predictor;
use tlora::scheduler::{schedule, Candidate};
use tlora::sim::{simulate, simulate_jobs};
use tlora::util::prop::{gen_pair, gen_usize, prop_check};
use tlora::util::rng::Rng;
use tlora::workload::trace::{TraceGenerator, TraceProfile};
use tlora::workload::JobSpec;

fn scenario(seed: u64, k: usize)
    -> (ClusterSpec, Vec<Candidate>, Predictor, SchedulerConfig) {
    let spec = ClusterSpec::with_gpus((4 * k).max(16));
    let mut alloc = Allocator::new(spec.clone());
    let mut pred = Predictor::new(spec.clone(), PlanOptions::default());
    let mut rng = Rng::new(seed ^ 0x5EED);
    let jobs: Vec<JobSpec> =
        TraceGenerator::new(TraceProfile::month1(), seed).generate(k);
    let cands = jobs
        .into_iter()
        .filter_map(|mut j| {
            j.gpus = *rng.choice(&[1usize, 1, 2]);
            let a = alloc.allocate(j.gpus)?;
            let residual = pred.residual(&j, &a).unwrap_or(0.5);
            Some(Candidate {
                job: j,
                alloc: a,
                urgency: rng.f64(),
                residual,
            })
        })
        .collect();
    (spec, cands, pred, SchedulerConfig::default())
}

#[test]
fn prop_no_round_exceeds_cluster_capacity() {
    let g = gen_pair(gen_usize(1, 4000), gen_usize(4, 14));
    prop_check(12, &g, |&(seed, k)| {
        let (spec, cands, mut pred, cfg) = scenario(seed as u64, k);
        let out = schedule(cands, &mut pred, &cfg);
        let mut seen = HashSet::new();
        let mut total = 0usize;
        for (grp, _) in &out.groups {
            for gpu in &grp.alloc.gpus {
                // within topology bounds
                if gpu.node >= spec.n_nodes
                    || gpu.idx >= spec.gpus_per_node
                {
                    return false;
                }
                // never assigned twice across groups (or within one)
                if !seen.insert(*gpu) {
                    return false;
                }
                total += 1;
            }
        }
        total <= spec.total_gpus()
    });
}

#[test]
fn prop_every_submitted_job_is_scheduled_each_round() {
    let g = gen_pair(gen_usize(1, 4000), gen_usize(4, 14));
    prop_check(12, &g, |&(seed, k)| {
        let (_, cands, mut pred, cfg) = scenario(seed as u64, k);
        let mut want: Vec<u64> =
            cands.iter().map(|c| c.job.id).collect();
        let out = schedule(cands, &mut pred, &cfg);
        let mut got: Vec<u64> = out
            .groups
            .iter()
            .flat_map(|(grp, _)| grp.jobs.iter().map(|j| j.id))
            .collect();
        want.sort_unstable();
        got.sort_unstable();
        got == want
    });
}

#[test]
fn prop_grouping_respects_solo_baseline_slowdown_bound() {
    let g = gen_pair(gen_usize(1, 4000), gen_usize(4, 12));
    prop_check(10, &g, |&(seed, k)| {
        let (_, cands, mut pred, cfg) = scenario(seed as u64, k);
        let out = schedule(cands, &mut pred, &cfg);
        for (grp, perf) in &out.groups {
            for j in &grp.jobs {
                // the job's nominal share of the merged gang: its first
                // `gpus` devices — the same baseline the predictor's
                // slowdown accounting uses
                let sub = Allocation {
                    gpus: grp
                        .alloc
                        .gpus
                        .iter()
                        .take(j.gpus.max(1).min(grp.alloc.gpus.len()))
                        .cloned()
                        .collect(),
                };
                let Ok(iso) = pred.isolated_step_time(j, &sub) else {
                    return false;
                };
                if perf.step_time_s
                    > iso * j.max_slowdown * (1.0 + 1e-9)
                {
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn prop_jobs_are_conserved_even_with_unsatisfiable_requests() {
    // 4. conservation — every submitted job ends the run in exactly one
    //    of `jct` or `incomplete_jobs`, even when the workload contains
    //    a request the cluster can never place (the old horizon loop
    //    silently dropped those); and the engine terminates promptly
    //    instead of spinning to its t_max valve
    prop_check(8, &gen_usize(0, 10_000), |&seed| {
        let mut cfg = ExperimentConfig::default();
        cfg.policy = Policy::TLora;
        cfg.n_jobs = 8 + seed % 6;
        cfg.cluster = ClusterSpec::with_gpus(16);
        cfg.seed = seed as u64;
        let mut jobs =
            TraceGenerator::new(cfg.trace.clone(), cfg.seed)
                .generate(cfg.n_jobs);
        let mut big = jobs[0].clone();
        big.id = 10_000;
        big.gpus = 999; // can never own an allocation
        jobs.push(big);
        let n = jobs.len();
        let r = simulate_jobs(&cfg, jobs);
        let mut seen: Vec<u64> = r
            .jct
            .iter()
            .map(|&(id, _)| id)
            .chain(r.incomplete_jobs.iter().copied())
            .collect();
        seen.sort_unstable();
        let distinct = {
            let mut d = seen.clone();
            d.dedup();
            d.len()
        };
        seen.len() == n && distinct == n && r.makespan < 1e6
    });
}

#[test]
fn prop_simulator_eventually_schedules_every_job() {
    // liveness end-to-end: across seeds, loads, and policies, every
    // submitted job completes (none starves in the queue forever)
    prop_check(8, &gen_usize(0, 10_000), |&seed| {
        for policy in [Policy::TLora, Policy::MLora] {
            let mut cfg = ExperimentConfig::default();
            cfg.policy = policy;
            cfg.n_jobs = 10 + seed % 8;
            cfg.cluster = ClusterSpec::with_gpus(16);
            cfg.seed = seed as u64;
            cfg.trace = TraceProfile::month1().scaled(3.0);
            let r = simulate(&cfg);
            if r.jct.len() != cfg.n_jobs {
                return false;
            }
            if !r.jct.iter().all(|&(_, v)| v.is_finite() && v > 0.0) {
                return false;
            }
        }
        true
    });
}
