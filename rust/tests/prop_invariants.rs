//! Property tests (via the in-crate `util::prop` framework) for the
//! Adapter Scheduler's §3.4 invariants, checked independently of the
//! scheduler's own bookkeeping:
//!
//! 1. capacity — no scheduling round hands out more GPUs than the
//!    cluster has, never shares a GPU between groups, and never invents
//!    a GPU outside the cluster topology;
//! 2. liveness — every submitted job is scheduled into exactly one
//!    group each round, and at the simulator level every job eventually
//!    completes;
//! 3. bounded slowdown — grouping never raises a member's modeled
//!    per-step time above its solo baseline by more than its Δ^max,
//!    recomputed here from the predictor's isolated step time rather
//!    than trusting the scheduler's recorded slowdowns;
//! 4. the extended event queue — random batches over all eight event
//!    kinds pop in `(time, kind, job_id, epoch)` order and are a
//!    permutation of what was pushed; epoch staleness discards exactly
//!    the schedule-derived events with an older stamp;
//! 5. conservation under failure injection — with node churn and
//!    preemptions active, every job still ends the run in exactly one
//!    of `jct` / `incomplete_jobs`;
//! 6. straggler exactness — a single scripted multiplier `m` on a solo
//!    group's node stretches its completion by exactly the analytic
//!    amount (and restores exactly at the scripted instant);
//! 7. straggler robustness — rates stay finite and non-negative under
//!    random degrade/restore interleavings, and job conservation
//!    holds under seeded straggler churn (with and without node
//!    failures), mirroring the failure-churn property;
//! 8. graceful degradation — with wear-coupled single-GPU churn and
//!    shrink-in-place active, jobs are still conserved, shrink
//!    bookkeeping stays consistent (only capable policies shrink,
//!    every regrow pairs with a prior shrink), and the run replays
//!    bit-identically;
//! 9. degradation monotonicity — dropping one GPU from a single-node
//!    gang never lowers the predictor's modeled step time.

use std::collections::HashSet;

use tlora::cluster::{Allocation, Allocator, ClusterSpec, GpuId};
use tlora::config::{ExperimentConfig, Policy, SchedulerConfig};
use tlora::planner::PlanOptions;
use tlora::scheduler::predictor::Predictor;
use tlora::scheduler::{schedule, Candidate};
use tlora::sim::events::{Event, EventKind, EventQueue};
use tlora::sim::{simulate, simulate_jobs};
use tlora::util::f64_cmp;
use tlora::util::prop::{
    gen_f64, gen_pair, gen_usize, gen_vec, prop_check,
};
use tlora::util::rng::Rng;
use tlora::workload::trace::{TraceGenerator, TraceProfile};
use tlora::workload::JobSpec;

fn scenario(seed: u64, k: usize)
    -> (ClusterSpec, Vec<Candidate>, Predictor, SchedulerConfig) {
    let spec = ClusterSpec::with_gpus((4 * k).max(16));
    let mut alloc = Allocator::new(spec.clone());
    let mut pred = Predictor::new(spec.clone(), PlanOptions::default());
    let mut rng = Rng::new(seed ^ 0x5EED);
    let jobs: Vec<JobSpec> =
        TraceGenerator::new(TraceProfile::month1(), seed).generate(k);
    let cands = jobs
        .into_iter()
        .filter_map(|mut j| {
            j.gpus = *rng.choice(&[1usize, 1, 2]);
            let a = alloc.allocate(j.gpus)?;
            let residual = pred.residual(&j, &a).unwrap_or(0.5);
            Some(Candidate {
                job: j,
                alloc: a,
                urgency: rng.f64(),
                residual,
            })
        })
        .collect();
    (spec, cands, pred, SchedulerConfig::default())
}

#[test]
fn prop_no_round_exceeds_cluster_capacity() {
    let g = gen_pair(gen_usize(1, 4000), gen_usize(4, 14));
    prop_check(12, &g, |&(seed, k)| {
        let (spec, cands, mut pred, cfg) = scenario(seed as u64, k);
        let out = schedule(cands, &mut pred, &cfg);
        let mut seen = HashSet::new();
        let mut total = 0usize;
        for (grp, _) in &out.groups {
            for gpu in &grp.alloc.gpus {
                // within topology bounds
                if gpu.node >= spec.n_nodes
                    || gpu.idx >= spec.gpus_per_node
                {
                    return false;
                }
                // never assigned twice across groups (or within one)
                if !seen.insert(*gpu) {
                    return false;
                }
                total += 1;
            }
        }
        total <= spec.total_gpus()
    });
}

#[test]
fn prop_every_submitted_job_is_scheduled_each_round() {
    let g = gen_pair(gen_usize(1, 4000), gen_usize(4, 14));
    prop_check(12, &g, |&(seed, k)| {
        let (_, cands, mut pred, cfg) = scenario(seed as u64, k);
        let mut want: Vec<u64> =
            cands.iter().map(|c| c.job.id).collect();
        let out = schedule(cands, &mut pred, &cfg);
        let mut got: Vec<u64> = out
            .groups
            .iter()
            .flat_map(|(grp, _)| grp.jobs.iter().map(|j| j.id))
            .collect();
        want.sort_unstable();
        got.sort_unstable();
        got == want
    });
}

#[test]
fn prop_grouping_respects_solo_baseline_slowdown_bound() {
    let g = gen_pair(gen_usize(1, 4000), gen_usize(4, 12));
    prop_check(10, &g, |&(seed, k)| {
        let (_, cands, mut pred, cfg) = scenario(seed as u64, k);
        let out = schedule(cands, &mut pred, &cfg);
        for (grp, perf) in &out.groups {
            for j in &grp.jobs {
                // the job's nominal share of the merged gang: its first
                // `gpus` devices — the same baseline the predictor's
                // slowdown accounting uses
                let sub = Allocation {
                    gpus: grp
                        .alloc
                        .gpus
                        .iter()
                        .take(j.gpus.max(1).min(grp.alloc.gpus.len()))
                        .cloned()
                        .collect(),
                };
                let Ok(iso) = pred.isolated_step_time(j, &sub) else {
                    return false;
                };
                if perf.step_time_s
                    > iso * j.max_slowdown * (1.0 + 1e-9)
                {
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn prop_jobs_are_conserved_even_with_unsatisfiable_requests() {
    // 4. conservation — every submitted job ends the run in exactly one
    //    of `jct` or `incomplete_jobs`, even when the workload contains
    //    a request the cluster can never place (the old horizon loop
    //    silently dropped those); and the engine terminates promptly
    //    instead of spinning to its t_max valve
    prop_check(8, &gen_usize(0, 10_000), |&seed| {
        let mut cfg = ExperimentConfig::default();
        cfg.policy = Policy::TLora;
        cfg.n_jobs = 8 + seed % 6;
        cfg.cluster = ClusterSpec::with_gpus(16);
        cfg.seed = seed as u64;
        let mut jobs =
            TraceGenerator::new(cfg.trace.clone(), cfg.seed)
                .generate(cfg.n_jobs);
        let mut big = jobs[0].clone();
        big.id = 10_000;
        big.gpus = 999; // can never own an allocation
        jobs.push(big);
        let n = jobs.len();
        let r = simulate_jobs(&cfg, jobs);
        let mut seen: Vec<u64> = r
            .jct
            .iter()
            .map(|&(id, _)| id)
            .chain(r.incomplete_jobs.iter().copied())
            .collect();
        seen.sort_unstable();
        let distinct = {
            let mut d = seen.clone();
            d.dedup();
            d.len()
        };
        seen.len() == n && distinct == n && r.makespan < 1e6
    });
}

// ---------------------------------------------------------------------
// Extended event queue: ordering, permutation, staleness
// ---------------------------------------------------------------------

const ALL_KINDS: [EventKind; 8] = [
    EventKind::Arrival,
    EventKind::Completion,
    EventKind::NodeFailure,
    EventKind::NodeRecovery,
    EventKind::NodeDegraded,
    EventKind::NodeRestored,
    EventKind::Preemption,
    EventKind::ReschedulePoint,
];

/// The documented tie-break rank, restated as the spec the queue must
/// satisfy (events.rs keeps its own copy private).
fn kind_rank(k: EventKind) -> u8 {
    match k {
        EventKind::Arrival => 0,
        EventKind::Completion => 1,
        EventKind::NodeFailure => 2,
        EventKind::NodeRecovery => 3,
        EventKind::NodeDegraded => 4,
        EventKind::NodeRestored => 5,
        EventKind::Preemption => 6,
        EventKind::ReschedulePoint => 7,
    }
}

/// Encoded random event: ((time_ticks, kind_idx), (job_id, epoch)).
/// Times are small integers so equal timestamps (the interesting
/// tie-break case) occur constantly.
type EncodedEvent = ((usize, usize), (usize, usize));

fn decode(e: &EncodedEvent) -> Event {
    let ((ticks, kind), (job, epoch)) = *e;
    Event {
        time: ticks as f64 * 0.5,
        kind: ALL_KINDS[kind],
        job_id: job as u64,
        epoch: epoch as u64,
    }
}

fn event_key(e: &Event) -> (u64, u8, u64, u64) {
    (e.time.to_bits(), kind_rank(e.kind), e.job_id, e.epoch)
}

#[test]
fn prop_event_queue_pops_in_time_kind_job_epoch_order() {
    let g = gen_vec(
        gen_pair(
            gen_pair(gen_usize(0, 12), gen_usize(0, 7)),
            gen_pair(gen_usize(0, 6), gen_usize(0, 3)),
        ),
        0,
        60,
    );
    prop_check(150, &g, |encoded| {
        let mut q = EventQueue::new();
        for e in encoded {
            q.push(decode(e));
        }
        let popped: Vec<Event> =
            std::iter::from_fn(|| q.pop()).collect();
        if popped.len() != encoded.len() {
            return false;
        }
        // sorted under the documented comparator (times here are
        // non-negative, so to_bits order == numeric order)
        let ordered = popped.windows(2).all(|w| {
            f64_cmp(w[0].time, w[1].time)
                .then(
                    kind_rank(w[0].kind).cmp(&kind_rank(w[1].kind)),
                )
                .then(w[0].job_id.cmp(&w[1].job_id))
                .then(w[0].epoch.cmp(&w[1].epoch))
                != std::cmp::Ordering::Greater
        });
        // and a permutation of the input
        let mut want: Vec<_> =
            encoded.iter().map(|e| event_key(&decode(e))).collect();
        let mut got: Vec<_> =
            popped.iter().map(event_key).collect();
        want.sort_unstable();
        got.sort_unstable();
        ordered && want == got
    });
}

#[test]
fn prop_stale_epoch_events_are_discarded_exactly() {
    let g = gen_pair(
        gen_vec(
            gen_pair(
                gen_pair(gen_usize(0, 12), gen_usize(0, 7)),
                gen_pair(gen_usize(0, 6), gen_usize(0, 3)),
            ),
            0,
            60,
        ),
        gen_usize(0, 3),
    );
    prop_check(150, &g, |(encoded, current)| {
        let current = *current as u64;
        let mut q = EventQueue::new();
        for e in encoded {
            q.push(decode(e));
        }
        // engine-style drain: drop stale events on pop
        let mut kept = 0usize;
        let mut discarded = 0usize;
        while let Some(ev) = q.pop() {
            if ev.is_stale(current) {
                discarded += 1;
            } else {
                kept += 1;
            }
        }
        // exactly the schedule-derived events with an older stamp go;
        // exogenous kinds (arrival, faults) always survive
        let want_discarded = encoded
            .iter()
            .map(decode)
            .filter(|e| {
                matches!(
                    e.kind,
                    EventKind::Completion
                        | EventKind::ReschedulePoint
                ) && e.epoch != current
            })
            .count();
        discarded == want_discarded
            && kept == encoded.len() - want_discarded
    });
}

// ---------------------------------------------------------------------
// Conservation under failure injection
// ---------------------------------------------------------------------

#[test]
fn prop_jobs_conserved_under_node_churn_and_preemption() {
    // with MTBF-driven node failures and Poisson preemptions active,
    // no job may vanish or be double-counted: each ends in exactly one
    // of `jct` / `incomplete_jobs`, and eviction bookkeeping stays
    // consistent (restarts imply a fault source)
    prop_check(6, &gen_usize(0, 10_000), |&seed| {
        for policy in [Policy::TLora, Policy::Megatron] {
            let mut cfg = ExperimentConfig::default();
            cfg.policy = policy;
            cfg.n_jobs = 10 + seed % 6;
            cfg.cluster = ClusterSpec::with_gpus(16);
            cfg.seed = seed as u64;
            cfg.trace = TraceProfile::month1().scaled(2.0);
            cfg.faults.mtbf_s = 2_000.0 + (seed % 5) as f64 * 500.0;
            cfg.faults.mttr_s = 200.0;
            cfg.faults.preempt_rate = 1.0 / 5_000.0;
            let r = simulate(&cfg);
            let mut seen: Vec<u64> = r
                .jct
                .iter()
                .map(|&(id, _)| id)
                .chain(r.incomplete_jobs.iter().copied())
                .collect();
            seen.sort_unstable();
            let n_seen = seen.len();
            seen.dedup();
            if n_seen != cfg.n_jobs || seen.len() != cfg.n_jobs {
                return false;
            }
            if !r.jct.iter().all(|&(_, v)| v.is_finite() && v > 0.0) {
                return false;
            }
            // churn accounting is internally consistent
            if r.restarts < r.preemptions {
                return false;
            }
            if r.restarts > 0
                && r.node_failures == 0
                && r.preemptions == 0
            {
                return false;
            }
            if r.lost_step_time_s < 0.0 || r.restore_delay_s < 0.0 {
                return false;
            }
        }
        true
    });
}

// ---------------------------------------------------------------------
// Graceful degradation (shrink-in-place)
// ---------------------------------------------------------------------

#[test]
fn prop_jobs_conserved_under_gpu_churn_with_shrink() {
    // 8. with wear-coupled single-GPU churn and shrink-in-place
    //    active, every job still ends the run in exactly one of
    //    `jct` / `incomplete_jobs`; shrink bookkeeping stays
    //    consistent — only shrink-capable policies shrink, every
    //    regrow consumes a partial allocation a prior shrink created,
    //    a shrink implies a GPU fault, and degraded-rate time only
    //    accrues when something shrank — and the whole run replays
    //    bit-identically from the same seed
    prop_check(6, &gen_usize(0, 10_000), |&seed| {
        for policy in [Policy::TLora, Policy::Megatron] {
            let mut cfg = ExperimentConfig::default();
            cfg.policy = policy;
            cfg.n_jobs = 10 + seed % 6;
            cfg.cluster = ClusterSpec::with_gpus(16);
            cfg.seed = seed as u64;
            cfg.trace = TraceProfile::month1().scaled(2.0);
            cfg.faults.gpu_mtbf_s =
                15_000.0 + (seed % 5) as f64 * 2_000.0;
            cfg.faults.gpu_mttr_s = 400.0;
            cfg.faults.gpu_wear_alpha = 0.5;
            cfg.faults.shrink = true;
            let r = simulate(&cfg);
            let mut seen: Vec<u64> = r
                .jct
                .iter()
                .map(|&(id, _)| id)
                .chain(r.incomplete_jobs.iter().copied())
                .collect();
            seen.sort_unstable();
            let n_seen = seen.len();
            seen.dedup();
            if n_seen != cfg.n_jobs || seen.len() != cfg.n_jobs {
                return false;
            }
            if !r.jct.iter().all(|&(_, v)| v.is_finite() && v > 0.0) {
                return false;
            }
            // shrink accounting is internally consistent
            if policy == Policy::Megatron
                && (r.shrinks != 0
                    || r.regrows != 0
                    || r.degraded_rate_time_s != 0.0)
            {
                return false;
            }
            if r.regrows > r.shrinks {
                return false;
            }
            if r.shrinks > 0 && r.gpu_failures == 0 {
                return false;
            }
            if !(r.degraded_rate_time_s.is_finite()
                && r.degraded_rate_time_s >= 0.0)
            {
                return false;
            }
            if r.shrinks == 0 && r.degraded_rate_time_s != 0.0 {
                return false;
            }
            // deterministic replay, shrink path included
            let r2 = simulate(&cfg);
            if r2.jct != r.jct
                || r2.shrinks != r.shrinks
                || r2.regrows != r.regrows
                || r2.degraded_rate_time_s.to_bits()
                    != r.degraded_rate_time_s.to_bits()
            {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_single_node_shrink_never_speeds_a_gang_up() {
    // 9. degradation monotonicity — dropping one GPU from a
    //    single-node gang (the shrink-in-place move) never lowers the
    //    modeled step time: width n-1 on the same node is at most as
    //    fast as width n. Cross-node gangs are excluded on purpose —
    //    shrinking a gang off a second node can *remove* an
    //    inter-node hop and legitimately speed it up, which is why
    //    the simulator's spill rule re-prices the shrunken plan
    //    instead of assuming it got slower.
    let spec = ClusterSpec::with_gpus(8);
    let g = gen_pair(gen_usize(1, 4000), gen_usize(2, 8));
    prop_check(16, &g, |&(seed, width)| {
        let mut pred =
            Predictor::new(spec.clone(), PlanOptions::default());
        let mut job =
            TraceGenerator::new(TraceProfile::month1(), seed as u64)
                .generate(1)
                .pop()
                .unwrap();
        job.gpus = width;
        let full = Allocation {
            gpus: (0..width)
                .map(|i| GpuId { node: 0, idx: i })
                .collect(),
        };
        let shrunk = Allocation {
            gpus: (0..width - 1)
                .map(|i| GpuId { node: 0, idx: i })
                .collect(),
        };
        let jobs = [job];
        let Some(p_full) = pred.group_perf(&jobs, &full) else {
            return false;
        };
        // mirror the engine: the hole is recorded before the
        // surviving-width re-plan prices the shrunken gang
        pred.set_node_holes(0, 1);
        let Some(p_shrunk) = pred.group_perf(&jobs, &shrunk) else {
            return false;
        };
        p_shrunk.step_time_s >= p_full.step_time_s * (1.0 - 1e-9)
    });
}

// ---------------------------------------------------------------------
// Straggler properties
// ---------------------------------------------------------------------

/// One 1-GPU job on an otherwise empty cluster: Megatron keeps it solo
/// with no AIMD, so its step rate is the analytic planner rate and the
/// straggler algebra is exact.
fn solo_job(total_steps: u64) -> JobSpec {
    JobSpec {
        id: 0,
        base_model: "llama3-8b".into(),
        rank: 8,
        batch_size: 4,
        seq_len: 512,
        gpus: 1,
        total_steps,
        submit_time: 0.0,
        max_slowdown: 100.0,
    }
}

fn solo_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.policy = Policy::Megatron;
    cfg.n_jobs = 1;
    cfg.cluster = ClusterSpec::with_gpus(16);
    cfg
}

fn run_solo(
    script: Vec<tlora::workload::faults::ScriptedStraggler>,
) -> tlora::sim::SimResult {
    let opts = tlora::sim::EngineOptions {
        straggler_script: script,
        ..tlora::sim::EngineOptions::default()
    };
    tlora::sim::simulate_jobs_with(
        &solo_cfg(),
        vec![solo_job(200)],
        &opts,
        &mut [],
    )
}

#[test]
fn prop_scripted_multiplier_scales_solo_throughput_exactly() {
    // 6. exactness — a node degraded to speed m from t=0 stretches a
    //    solo job's completion by exactly 1/m (measured throughput
    //    scales by exactly m), and a scripted restore at t2 switches
    //    the rate at exactly that instant:
    //    jct = t2 + (jct_baseline - t2 * m)
    use tlora::workload::faults::ScriptedStraggler;
    let baseline = run_solo(vec![]);
    assert_eq!(baseline.jct.len(), 1);
    let jct0 = baseline.jct[0].1;
    assert!(jct0 > 0.0 && jct0.is_finite());
    prop_check(8, &gen_f64(0.2, 0.9), |&m| {
        // degraded for the whole run: slowdown is exactly 1/m
        let degraded = run_solo(vec![ScriptedStraggler {
            time: 0.0,
            node: 0,
            speed: m,
        }]);
        if degraded.jct.len() != 1 {
            return false;
        }
        let jct1 = degraded.jct[0].1;
        if !((jct1 * m - jct0).abs() <= 1e-9 * jct0) {
            return false;
        }
        if degraded.node_degrades != 1 {
            return false;
        }
        // degraded metrics: the node stayed degraded to the end
        if (degraded.degraded_node_time_s - degraded.makespan).abs()
            > 1e-9 * degraded.makespan
        {
            return false;
        }
        if (degraded.straggler_slowdown - 1.0 / m).abs() > 1e-9 / m {
            return false;
        }
        // restore mid-run: the rate switches exactly at t2
        let t2 = 0.5 * jct1;
        let restored = run_solo(vec![
            ScriptedStraggler {
                time: 0.0,
                node: 0,
                speed: m,
            },
            ScriptedStraggler {
                time: t2,
                node: 0,
                speed: 1.0,
            },
        ]);
        if restored.jct.len() != 1 {
            return false;
        }
        let want = t2 + (jct0 - t2 * m);
        (restored.jct[0].1 - want).abs() <= 1e-9 * want
    });
}

#[test]
fn prop_rates_stay_finite_under_random_straggler_interleavings() {
    // 7a. robustness — arbitrary degrade/restore interleavings (wrong
    //     orders, repeated degrades, restores of healthy nodes) never
    //     produce non-finite rates, negative accounting, or lost jobs
    use tlora::workload::faults::ScriptedStraggler;
    let g = gen_pair(
        gen_usize(1, 4000),
        gen_vec(
            gen_pair(
                gen_pair(gen_f64(0.0, 3000.0), gen_usize(0, 1)),
                gen_f64(0.15, 1.3),
            ),
            1,
            12,
        ),
    );
    prop_check(10, &g, |(seed, raw)| {
        let mut seen = HashSet::new();
        let script: Vec<ScriptedStraggler> = raw
            .iter()
            .map(|&((time, node), speed)| ScriptedStraggler {
                time,
                node: node as u64,
                speed,
            })
            // the engine rejects two entries for one (time, node);
            // random (and especially shrunken) scripts may collide
            .filter(|e| seen.insert((e.time.to_bits(), e.node)))
            .collect();
        for policy in [Policy::TLora, Policy::Megatron] {
            let mut cfg = ExperimentConfig::default();
            cfg.policy = policy;
            cfg.n_jobs = 8;
            cfg.cluster = ClusterSpec::with_gpus(16);
            cfg.seed = *seed as u64;
            cfg.trace = TraceProfile::month1().scaled(2.0);
            let jobs =
                TraceGenerator::new(cfg.trace.clone(), cfg.seed)
                    .generate(cfg.n_jobs);
            let opts = tlora::sim::EngineOptions {
                straggler_script: script.clone(),
                ..tlora::sim::EngineOptions::default()
            };
            let r = tlora::sim::simulate_jobs_with(
                &cfg,
                jobs,
                &opts,
                &mut [],
            );
            if !r.jct.iter().all(|&(_, v)| v.is_finite() && v > 0.0) {
                return false;
            }
            if r.jct.len() + r.incomplete_jobs.len() != cfg.n_jobs {
                return false;
            }
            if !(r.makespan.is_finite() && r.makespan >= 0.0) {
                return false;
            }
            if !(r.degraded_node_time_s.is_finite()
                && r.degraded_node_time_s >= 0.0)
            {
                return false;
            }
            if !(r.straggler_slowdown.is_finite()
                && r.straggler_slowdown > 0.0)
            {
                return false;
            }
            if !(r.goodput.is_finite() && r.goodput >= 0.0) {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_jobs_conserved_under_straggler_churn() {
    // 7b. conservation — with the seeded straggler model active (and
    //     node failures layered on top for half the cases), every job
    //     still ends the run in exactly one of `jct` /
    //     `incomplete_jobs`, and straggler accounting stays consistent
    prop_check(6, &gen_usize(0, 10_000), |&seed| {
        for policy in [Policy::TLora, Policy::Megatron] {
            let mut cfg = ExperimentConfig::default();
            cfg.policy = policy;
            cfg.n_jobs = 10 + seed % 6;
            cfg.cluster = ClusterSpec::with_gpus(16);
            cfg.seed = seed as u64;
            cfg.trace = TraceProfile::month1().scaled(2.0);
            cfg.stragglers.mtbs_s =
                1_500.0 + (seed % 5) as f64 * 400.0;
            cfg.stragglers.mtts_s = 300.0;
            if seed % 2 == 0 {
                // straggler + failure churn together
                cfg.faults.mtbf_s = 3_000.0;
                cfg.faults.mttr_s = 200.0;
            }
            let r = simulate(&cfg);
            let mut seen: Vec<u64> = r
                .jct
                .iter()
                .map(|&(id, _)| id)
                .chain(r.incomplete_jobs.iter().copied())
                .collect();
            seen.sort_unstable();
            let n_seen = seen.len();
            seen.dedup();
            if n_seen != cfg.n_jobs || seen.len() != cfg.n_jobs {
                return false;
            }
            if !r.jct.iter().all(|&(_, v)| v.is_finite() && v > 0.0) {
                return false;
            }
            // straggler accounting is internally consistent
            if r.node_degrades == 0
                && (r.degraded_node_time_s != 0.0
                    || r.migrations != 0)
            {
                return false;
            }
            if r.straggler_slowdown < 1.0
                || !r.straggler_slowdown.is_finite()
            {
                return false;
            }
            // only detection-aware policies migrate
            if policy == Policy::Megatron && r.migrations != 0 {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_simulator_eventually_schedules_every_job() {
    // liveness end-to-end: across seeds, loads, and policies, every
    // submitted job completes (none starves in the queue forever)
    prop_check(8, &gen_usize(0, 10_000), |&seed| {
        for policy in [Policy::TLora, Policy::MLora] {
            let mut cfg = ExperimentConfig::default();
            cfg.policy = policy;
            cfg.n_jobs = 10 + seed % 8;
            cfg.cluster = ClusterSpec::with_gpus(16);
            cfg.seed = seed as u64;
            cfg.trace = TraceProfile::month1().scaled(3.0);
            let r = simulate(&cfg);
            if r.jct.len() != cfg.n_jobs {
                return false;
            }
            if !r.jct.iter().all(|&(_, v)| v.is_finite() && v > 0.0) {
                return false;
            }
        }
        true
    });
}
