//! Differential regressions for the performance subsystem: the
//! shape-level plan cache and the dirty-group completion
//! re-derivation must be *pure* optimizations — every canonical
//! output bit identical to the unoptimized paths, only the cost
//! counters allowed to move.
//!
//! Two switchable reference modes make that checkable inside one
//! build (no blessed fixture needed):
//!
//! * `EngineOptions::plan_shape_cache = false` — *cold* predictor:
//!   every plan-level consult runs the planner;
//! * `EngineOptions::global_reissue = true` — the pre-dirty-set
//!   behavior: every running job's completion re-pushed every round
//!   (with its anchored instant), per-round epoch churn included.

use tlora::config::Policy;
use tlora::sim::{simulate_jobs_with, EngineOptions};
use tlora::sweep::{to_json_canonical, PointResult, SweepGrid, SweepRun};
use tlora::workload::trace::TraceGenerator;

/// The golden grid (tests/integration_golden.rs), reused so these
/// differentials cover the exact scenarios the fixture pins: two
/// policies, fault-free and faulted cells, two seeds.
fn golden_grid() -> SweepGrid {
    let mut g = SweepGrid::default();
    g.policies = vec![Policy::TLora, Policy::Megatron];
    g.n_jobs = vec![10];
    g.gpus = vec![16];
    g.rate_scales = vec![2.0];
    g.months = vec![1];
    g.mtbfs = vec![0.0, 900.0];
    g.seeds = vec![7, 8];
    g
}

/// A straggler-active cell: exercises `set_node_speed` re-pricing and
/// detection-driven migration through the dirty-set machinery.
fn straggler_grid() -> SweepGrid {
    let mut g = SweepGrid::default();
    g.policies = vec![Policy::TLora];
    g.n_jobs = vec![10];
    g.gpus = vec![16];
    g.rate_scales = vec![2.0];
    g.months = vec![1];
    g.stragglers = vec![600.0];
    g.seeds = vec![7];
    g
}

/// Run every grid cell serially under explicit engine options (the
/// sweep runner hard-codes the default options, so the differentials
/// drive the engine directly and assemble the run by hand).
fn run_with_opts(g: &SweepGrid, opts: &EngineOptions) -> SweepRun {
    let points = g
        .points()
        .into_iter()
        .map(|p| {
            let cfg = p.config(&g.base);
            let jobs =
                TraceGenerator::new(cfg.trace.clone(), cfg.seed)
                    .generate(cfg.n_jobs);
            let result = simulate_jobs_with(&cfg, jobs, opts, &mut []);
            PointResult {
                point: p,
                result,
                wall_s: 0.0,
            }
        })
        .collect();
    SweepRun {
        points,
        n_threads: 1,
        wall_s: 0.0,
    }
}

/// Zero the cost counters that the compared modes are *defined* to
/// disagree on, so the remaining canonical JSON — every simulated
/// quantity — must match byte for byte.
fn scrub(run: &mut SweepRun, probes: bool, stale: bool) {
    for p in &mut run.points {
        if probes {
            p.result.scheduler_probes = 0;
            p.result.plan_cache_hits = 0;
        }
        if stale {
            p.result.events_stale = 0;
        }
    }
}

#[test]
fn cached_vs_cold_golden_grid_is_byte_identical() {
    let g = golden_grid();
    let mut warm = run_with_opts(&g, &EngineOptions::default());
    let mut cold = run_with_opts(
        &g,
        &EngineOptions {
            plan_shape_cache: false,
            ..EngineOptions::default()
        },
    );
    let warm_probes: u64 = warm
        .points
        .iter()
        .map(|p| p.result.scheduler_probes)
        .sum();
    let cold_probes: u64 = cold
        .points
        .iter()
        .map(|p| p.result.scheduler_probes)
        .sum();
    assert!(
        warm_probes < cold_probes,
        "shape cache saved nothing: {warm_probes} vs {cold_probes}"
    );
    // the acceptance bar: >=30% fewer planner evaluations on the
    // pinned dense-arrival grid (in practice the per-round residual
    // refresh alone collapses far more than that)
    assert!(
        (warm_probes as f64) <= 0.7 * cold_probes as f64,
        "probe drop under 30%: {warm_probes} vs {cold_probes}"
    );
    for (w, c) in warm.points.iter().zip(&cold.points) {
        assert_eq!(
            w.result.sched_rounds, c.result.sched_rounds,
            "{}: caching changed the round count",
            w.point.label()
        );
        assert_eq!(
            w.result.events, c.result.events,
            "{}: caching changed the event stream",
            w.point.label()
        );
    }
    // only the cost counters may differ; every simulated output bit
    // must survive the cache
    scrub(&mut warm, true, false);
    scrub(&mut cold, true, false);
    assert_eq!(
        to_json_canonical(&warm).to_pretty(),
        to_json_canonical(&cold).to_pretty(),
        "the shape-level plan cache changed simulation output"
    );
}

#[test]
fn dirty_vs_global_completion_reissue_is_byte_identical() {
    // property (satellite): per-job completion epochs discard exactly
    // the events a global per-round bump would have — the valid-event
    // stream, and therefore every output byte, is identical; only the
    // stale-discard churn differs (and must be strictly *lower* under
    // the dirty set)
    for (name, g) in
        [("golden", golden_grid()), ("straggler", straggler_grid())]
    {
        let mut dirty = run_with_opts(&g, &EngineOptions::default());
        let mut global = run_with_opts(
            &g,
            &EngineOptions {
                global_reissue: true,
                ..EngineOptions::default()
            },
        );
        let stale_dirty: u64 = dirty
            .points
            .iter()
            .map(|p| p.result.events_stale)
            .sum();
        let stale_global: u64 = global
            .points
            .iter()
            .map(|p| p.result.events_stale)
            .sum();
        assert!(
            stale_dirty < stale_global,
            "{name}: dirty-set derivation discarded {stale_dirty} \
             stale events vs global reissue's {stale_global} — no \
             heap-churn win"
        );
        for (d, gl) in dirty.points.iter().zip(&global.points) {
            assert_eq!(
                d.result.events,
                gl.result.events,
                "{name}/{}: valid-event streams diverged",
                d.point.label()
            );
            assert_eq!(
                d.result.jct.len() + d.result.incomplete_jobs.len(),
                d.point.n_jobs,
                "{name}/{}: job conservation",
                d.point.label()
            );
        }
        scrub(&mut dirty, false, true);
        scrub(&mut global, false, true);
        assert_eq!(
            to_json_canonical(&dirty).to_pretty(),
            to_json_canonical(&global).to_pretty(),
            "{name}: dirty-set completion re-derivation changed \
             simulation output"
        );
    }
}

#[test]
fn dirty_reissue_composes_with_cold_cache() {
    // the two optimizations are orthogonal: flipping both reference
    // switches at once still reproduces the optimized output
    let g = straggler_grid();
    let mut fast = run_with_opts(&g, &EngineOptions::default());
    let mut slow = run_with_opts(
        &g,
        &EngineOptions {
            plan_shape_cache: false,
            global_reissue: true,
            ..EngineOptions::default()
        },
    );
    scrub(&mut fast, true, true);
    scrub(&mut slow, true, true);
    assert_eq!(
        to_json_canonical(&fast).to_pretty(),
        to_json_canonical(&slow).to_pretty()
    );
}
