//! Integration: the trace-driven simulator end-to-end — policy
//! orderings, conservation invariants, determinism, and property-based
//! checks with the in-crate prop framework.

use tlora::config::{ExperimentConfig, Policy};
use tlora::sim::{simulate, simulate_jobs};
use tlora::util::prop::{gen_usize, prop_check};
use tlora::workload::trace::{TraceGenerator, TraceProfile};

fn cfg(policy: Policy, n_jobs: usize, gpus: usize) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.policy = policy;
    c.n_jobs = n_jobs;
    c.cluster = tlora::cluster::ClusterSpec::with_gpus(gpus);
    c.seed = 1234;
    c
}

#[test]
fn paper_policy_ordering_holds_under_contention() {
    // the §4.2 ordering at a contended 32-GPU cluster:
    // tLoRA best on throughput and JCT; mLoRA below Megatron
    let r_t = simulate(&cfg(Policy::TLora, 60, 32));
    let r_ml = simulate(&cfg(Policy::MLora, 60, 32));
    let r_mg = simulate(&cfg(Policy::Megatron, 60, 32));
    assert!(
        r_t.avg_throughput > r_ml.avg_throughput,
        "tLoRA {} <= mLoRA {}",
        r_t.avg_throughput,
        r_ml.avg_throughput
    );
    assert!(
        r_t.mean_jct < r_ml.mean_jct,
        "tLoRA JCT {} >= mLoRA {}",
        r_t.mean_jct,
        r_ml.mean_jct
    );
    assert!(
        r_t.mean_jct <= r_mg.mean_jct * 1.05,
        "tLoRA JCT {} much worse than Megatron {}",
        r_t.mean_jct,
        r_mg.mean_jct
    );
}

#[test]
fn every_job_completes_exactly_once() {
    for policy in Policy::all() {
        let c = cfg(policy, 40, 32);
        let r = simulate(&c);
        assert_eq!(r.jct.len(), c.n_jobs, "{policy:?}");
        let mut ids: Vec<u64> = r.jct.iter().map(|&(id, _)| id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), c.n_jobs, "{policy:?} duplicated a job");
        assert!(r.jct.iter().all(|&(_, v)| v > 0.0));
    }
}

#[test]
fn deterministic_across_runs() {
    let c = cfg(Policy::TLora, 40, 32);
    let a = simulate(&c);
    let b = simulate(&c);
    assert_eq!(a.jct, b.jct);
    assert_eq!(a.horizons, b.horizons);
    assert!((a.avg_throughput - b.avg_throughput).abs() < 1e-9);
}

#[test]
fn utilization_and_throughput_bounds() {
    for policy in [Policy::TLora, Policy::MLora] {
        let r = simulate(&cfg(policy, 50, 32));
        assert!((0.0..=1.0).contains(&r.avg_gpu_util), "{policy:?}");
        assert!(r.avg_throughput >= 0.0);
        assert!(r.makespan > 0.0);
        for &(_, u) in &r.util_timeline {
            assert!((0.0..=1.0).contains(&u));
        }
    }
}

#[test]
fn bigger_cluster_never_hurts() {
    let small = simulate(&cfg(Policy::TLora, 60, 16));
    let big = simulate(&cfg(Policy::TLora, 60, 64));
    assert!(big.mean_jct <= small.mean_jct * 1.05);
}

#[test]
fn prop_all_jobs_complete_across_seeds_and_sizes() {
    // property: for any (seed, n_jobs, gpus) the simulator terminates
    // with every job completed and sane metrics
    let g = gen_usize(0, 10_000);
    prop_check(12, &g, |&seed| {
        let mut c = cfg(Policy::TLora, 12 + seed % 10, 16);
        c.seed = seed as u64;
        c.trace = TraceProfile::month1().scaled(2.0);
        let r = simulate(&c);
        r.jct.len() == c.n_jobs
            && r.avg_gpu_util <= 1.0
            && r.jct.iter().all(|&(_, v)| v.is_finite() && v >= 0.0)
    });
}

#[test]
fn explicit_job_list_roundtrip() {
    let jobs =
        TraceGenerator::new(TraceProfile::month2(), 3).generate(20);
    let c = cfg(Policy::TLora, 20, 32);
    let r = simulate_jobs(&c, jobs.clone());
    assert_eq!(r.jct.len(), jobs.len());
}

#[test]
fn grouping_ratio_keys_present_for_tlora() {
    let r = simulate(&cfg(Policy::TLora, 60, 32));
    for k in ["small", "medium", "large"] {
        assert!(
            r.grouping_ratio.contains_key(k),
            "missing class {k}: {:?}",
            r.grouping_ratio
        );
        let v = r.grouping_ratio[k];
        assert!((0.0..=1.0).contains(&v));
    }
}
