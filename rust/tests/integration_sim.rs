//! Integration: the event-driven simulator end-to-end — policy
//! orderings, conservation invariants, determinism, event-engine
//! cadence vs the legacy per-horizon loop, elastic shared admission,
//! property-based checks with the in-crate prop framework, and the
//! fault/SLO subsystem (scripted and seeded node churn, preemptions,
//! checkpoint-restore accounting, goodput orderings).

use tlora::config::{ExperimentConfig, Policy};
use tlora::sim::{
    simulate, simulate_jobs, simulate_jobs_with, EngineOptions,
    EvictCause, JobState, SimObserver, SimResult,
};
use tlora::util::prop::{gen_usize, prop_check};
use tlora::workload::faults::{FaultKind, ScriptedFault};
use tlora::workload::trace::{TraceGenerator, TraceProfile};
use tlora::workload::JobSpec;

fn cfg(policy: Policy, n_jobs: usize, gpus: usize) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.policy = policy;
    c.n_jobs = n_jobs;
    c.cluster = tlora::cluster::ClusterSpec::with_gpus(gpus);
    c.seed = 1234;
    c
}

#[test]
fn paper_policy_ordering_holds_under_contention() {
    // the §4.2 ordering at a contended 32-GPU cluster:
    // tLoRA best on throughput and JCT; mLoRA below Megatron
    let r_t = simulate(&cfg(Policy::TLora, 60, 32));
    let r_ml = simulate(&cfg(Policy::MLora, 60, 32));
    let r_mg = simulate(&cfg(Policy::Megatron, 60, 32));
    assert!(
        r_t.avg_throughput > r_ml.avg_throughput,
        "tLoRA {} <= mLoRA {}",
        r_t.avg_throughput,
        r_ml.avg_throughput
    );
    assert!(
        r_t.mean_jct < r_ml.mean_jct,
        "tLoRA JCT {} >= mLoRA {}",
        r_t.mean_jct,
        r_ml.mean_jct
    );
    assert!(
        r_t.mean_jct <= r_mg.mean_jct * 1.05,
        "tLoRA JCT {} much worse than Megatron {}",
        r_t.mean_jct,
        r_mg.mean_jct
    );
}

#[test]
fn every_job_completes_exactly_once() {
    for policy in Policy::all() {
        let c = cfg(policy, 40, 32);
        let r = simulate(&c);
        assert_eq!(r.jct.len(), c.n_jobs, "{policy:?}");
        let mut ids: Vec<u64> = r.jct.iter().map(|&(id, _)| id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), c.n_jobs, "{policy:?} duplicated a job");
        assert!(r.jct.iter().all(|&(_, v)| v > 0.0));
    }
}

#[test]
fn deterministic_across_runs() {
    let c = cfg(Policy::TLora, 40, 32);
    let a = simulate(&c);
    let b = simulate(&c);
    assert_eq!(a.jct, b.jct);
    assert_eq!(a.sched_rounds, b.sched_rounds);
    assert_eq!(a.events, b.events);
    assert_eq!(a.scheduler_probes, b.scheduler_probes);
    assert!((a.avg_throughput - b.avg_throughput).abs() < 1e-9);
}

#[test]
fn utilization_and_throughput_bounds() {
    for policy in [Policy::TLora, Policy::MLora] {
        let r = simulate(&cfg(policy, 50, 32));
        assert!((0.0..=1.0).contains(&r.avg_gpu_util), "{policy:?}");
        assert!(r.avg_throughput >= 0.0);
        assert!(r.makespan > 0.0);
        for &(_, u) in &r.util_timeline {
            assert!((0.0..=1.0).contains(&u));
        }
    }
}

#[test]
fn bigger_cluster_never_hurts() {
    let small = simulate(&cfg(Policy::TLora, 60, 16));
    let big = simulate(&cfg(Policy::TLora, 60, 64));
    assert!(big.mean_jct <= small.mean_jct * 1.05);
}

#[test]
fn prop_all_jobs_complete_across_seeds_and_sizes() {
    // property: for any (seed, n_jobs, gpus) the simulator terminates
    // with every job completed and sane metrics
    let g = gen_usize(0, 10_000);
    prop_check(12, &g, |&seed| {
        let mut c = cfg(Policy::TLora, 12 + seed % 10, 16);
        c.seed = seed as u64;
        c.trace = TraceProfile::month1().scaled(2.0);
        let r = simulate(&c);
        r.jct.len() == c.n_jobs
            && r.avg_gpu_util <= 1.0
            && r.jct.iter().all(|&(_, v)| v.is_finite() && v >= 0.0)
    });
}

#[test]
fn explicit_job_list_roundtrip() {
    let jobs =
        TraceGenerator::new(TraceProfile::month2(), 3).generate(20);
    let c = cfg(Policy::TLora, 20, 32);
    let r = simulate_jobs(&c, jobs.clone());
    assert_eq!(r.jct.len(), jobs.len());
}

#[test]
fn grouping_ratio_keys_present_for_tlora() {
    let r = simulate(&cfg(Policy::TLora, 60, 32));
    for k in ["small", "medium", "large"] {
        assert!(
            r.grouping_ratio.contains_key(k),
            "missing class {k}: {:?}",
            r.grouping_ratio
        );
        let v = r.grouping_ratio[k];
        assert!((0.0..=1.0).contains(&v));
    }
}

// ---------------------------------------------------------------------
// Event-engine cadence vs the legacy per-horizon loop
// ---------------------------------------------------------------------

fn long_job(
    id: u64,
    submit: f64,
    rank: usize,
    batch: usize,
    total_steps: u64,
) -> JobSpec {
    JobSpec {
        id,
        base_model: "llama3-8b".into(),
        rank,
        batch_size: batch,
        seq_len: 512,
        gpus: 2,
        total_steps,
        submit_time: submit,
        max_slowdown: 2.0,
    }
}

fn completion_ids(r: &SimResult) -> Vec<u64> {
    let mut ids: Vec<u64> = r.jct.iter().map(|&(id, _)| id).collect();
    ids.sort_unstable();
    ids
}

#[test]
fn sparse_trace_needs_fewer_rounds_and_probes_than_horizon_loop() {
    // a low-arrival-rate trace: three long jobs separated by huge idle
    // stretches. The legacy per-horizon cadence (EngineOptions::
    // legacy_tick reproduces it) burns an iteration every 60 s through
    // both the idle gaps and the quiet steady-state of each job; the
    // event engine jumps arrival -> completion and must use strictly
    // fewer iterations AND strictly fewer predictor probes while
    // completing exactly the same job set.
    let mut c = cfg(Policy::TLora, 3, 16);
    let jobs = vec![
        long_job(0, 0.0, 8, 4, 50_000),
        long_job(1, 50_000.0, 4, 2, 50_000),
        long_job(2, 100_000.0, 8, 4, 50_000),
    ];
    c.n_jobs = jobs.len();
    let sparse = simulate_jobs_with(
        &c,
        jobs.clone(),
        &EngineOptions::default(),
        &mut [],
    );
    let legacy = simulate_jobs_with(
        &c,
        jobs,
        &EngineOptions {
            legacy_tick: true,
            ..EngineOptions::default()
        },
        &mut [],
    );
    assert_eq!(
        completion_ids(&sparse),
        vec![0, 1, 2],
        "sparse run must complete every job"
    );
    assert_eq!(
        completion_ids(&sparse),
        completion_ids(&legacy),
        "same job completion set"
    );
    assert!(
        sparse.sched_rounds < legacy.sched_rounds,
        "event engine used {} rounds vs legacy {}",
        sparse.sched_rounds,
        legacy.sched_rounds
    );
    // since the shape-level plan cache landed, `scheduler_probes`
    // counts planner *evaluations* — the legacy cadence's extra rounds
    // re-query shapes the cache already holds, so compare total
    // predictor work (evaluations + cache-served queries): the
    // reactive engine must do strictly less of it, and never more
    // actual planning
    let sparse_work =
        sparse.scheduler_probes + sparse.plan_cache_hits;
    let legacy_work =
        legacy.scheduler_probes + legacy.plan_cache_hits;
    assert!(
        sparse_work < legacy_work,
        "event engine did {sparse_work} predictor queries vs legacy \
         {legacy_work}"
    );
    assert!(
        sparse.scheduler_probes <= legacy.scheduler_probes,
        "event engine ran the planner {} times vs legacy {}",
        sparse.scheduler_probes,
        legacy.scheduler_probes
    );
    // legacy_tick upper-bounds the old loop (it adds reactive rounds
    // the old loop lacked), so also pin the engine against the old
    // loop's *analytic* costs: one iteration per horizon from t=0 to
    // the last completion, and at least one residual probe per horizon
    // in which a job was running (residuals were uncached planner runs
    // in the old loop, so busy_horizons lower-bounds its probe count).
    let horizon = c.scheduler.horizon_s;
    let old_loop_iters = (sparse.makespan / horizon).ceil() as u64;
    assert!(
        sparse.sched_rounds < old_loop_iters,
        "{} rounds vs the old loop's {} horizon iterations",
        sparse.sched_rounds,
        old_loop_iters
    );
    // jobs never wait here (idle cluster at every arrival), so Σ jct
    // is exactly the total busy time the old loop ticked through
    let busy_horizons =
        sparse.jct_values().iter().sum::<f64>() / horizon;
    assert!(
        (sparse.scheduler_probes as f64) < busy_horizons,
        "{} probes vs the old loop's >= {:.0} busy-horizon probes",
        sparse.scheduler_probes,
        busy_horizons
    );
}

#[test]
fn event_engine_reacts_to_arrivals_between_horizon_boundaries() {
    // a job submitted at t=7s must be admitted at t=7s, not at the
    // next 60 s boundary — the engine's round timestamps prove it
    #[derive(Default)]
    struct Admits(Vec<(u64, f64)>);
    impl SimObserver for Admits {
        fn on_admit(&mut self, t: f64, job: &JobState) {
            self.0.push((job.spec.id, t));
        }
    }
    let mut c = cfg(Policy::TLora, 1, 16);
    let jobs = vec![long_job(0, 7.0, 8, 4, 100)];
    c.n_jobs = 1;
    let mut admits = Admits::default();
    let r = simulate_jobs_with(
        &c,
        jobs,
        &EngineOptions::default(),
        &mut [&mut admits],
    );
    assert_eq!(admits.0, vec![(0, 7.0)]);
    assert_eq!(r.jct.len(), 1);
}

// ---------------------------------------------------------------------
// Elastic shared admission through the full engine
// ---------------------------------------------------------------------

/// Records every admission and completion with the job's bookkeeping
/// at that moment, to pin the exactly-once contract.
#[derive(Default)]
struct AdmissionAudit {
    admits: Vec<(u64, f64, Option<f64>, f64)>,
    completes: Vec<(u64, f64, Option<f64>, f64, f64)>,
}

impl SimObserver for AdmissionAudit {
    fn on_admit(&mut self, t: f64, job: &JobState) {
        self.admits.push((
            job.spec.id,
            t,
            job.admitted_at,
            job.iso_step_time,
        ));
    }

    fn on_complete(&mut self, t: f64, job: &JobState) {
        self.completes.push((
            job.spec.id,
            t,
            job.admitted_at,
            job.iso_step_time,
            job.grouped_time,
        ));
    }
}

#[test]
fn queued_job_on_full_cluster_is_absorbed_elastically() {
    // single-GPU cluster: job 0 owns the only GPU for its whole (long)
    // run; job 1 arrives mid-run and can only make progress by being
    // absorbed into job 0's group (the Shared Super-Model mechanism)
    let mut c = cfg(Policy::TLora, 2, 1);
    let holder = JobSpec {
        gpus: 1,
        ..long_job(0, 0.0, 8, 4, 100_000)
    };
    let visitor = JobSpec {
        gpus: 1,
        ..long_job(1, 10.0, 4, 2, 500)
    };
    c.n_jobs = 2;
    let mut audit = AdmissionAudit::default();
    let r = simulate_jobs_with(
        &c,
        vec![holder.clone(), visitor.clone()],
        &EngineOptions::default(),
        &mut [&mut audit],
    );
    assert_eq!(r.jct.len(), 2, "both jobs must complete");
    assert!(r.incomplete_jobs.is_empty());

    // exactly one admission per job, despite the visitor being
    // dissolved and re-absorbed every scheduling round
    let mut admitted: Vec<u64> =
        audit.admits.iter().map(|a| a.0).collect();
    admitted.sort_unstable();
    assert_eq!(admitted, vec![0, 1], "one admission per job");

    let (_, t_admit, at_admit, iso_admit) = *audit
        .admits
        .iter()
        .find(|a| a.0 == visitor.id)
        .unwrap();
    assert_eq!(at_admit, Some(t_admit), "admitted_at set at absorption");
    assert!(iso_admit.is_finite() && iso_admit > 0.0);

    // the visitor finished while the holder still ran: with one GPU
    // and no preemption this is only possible via shared placement
    let done_t = |id: u64| {
        audit
            .completes
            .iter()
            .find(|cmp| cmp.0 == id)
            .map(|cmp| cmp.1)
            .unwrap()
    };
    assert!(t_admit > visitor.submit_time - 1e-9);
    assert!(
        done_t(visitor.id) < done_t(holder.id),
        "visitor must finish inside the shared group"
    );

    // ... and its admission bookkeeping never churned afterwards
    let (_, _, at_done, iso_done, grouped_time) = *audit
        .completes
        .iter()
        .find(|cmp| cmp.0 == visitor.id)
        .unwrap();
    assert_eq!(at_done, Some(t_admit), "admitted_at stayed put");
    assert_eq!(iso_done, iso_admit, "iso_step_time stayed put");
    assert!(grouped_time > 0.0, "visitor ran co-located");

    // the incumbent stayed within its Δ^max under the committed merge
    let mut pred = tlora::scheduler::Predictor::new(
        c.cluster.clone(),
        tlora::planner::PlanOptions {
            fused_kernel: c.policy.uses_kernel_fuser(),
            n_nano: Some(c.aimd.n0),
            n_nano_max: c.aimd.n_max,
        },
    );
    let mut alloc = tlora::cluster::Allocator::new(c.cluster.clone());
    let a = alloc.allocate(1).unwrap();
    let merged = pred
        .group_perf(&[holder.clone(), visitor.clone()], &a)
        .expect("merge must be feasible");
    assert!(
        merged.within_slowdown(std::slice::from_ref(&holder)),
        "absorption violated the incumbent's slowdown bound: {:?}",
        merged.slowdowns
    );
}

// ---------------------------------------------------------------------
// Silent-truncation fix: incomplete jobs are surfaced, not dropped
// ---------------------------------------------------------------------

// ---------------------------------------------------------------------
// Fault & SLO subsystem
// ---------------------------------------------------------------------

/// Records fault-path observer callbacks to pin the engine's contract.
#[derive(Default)]
struct FaultAudit {
    failures: Vec<(f64, usize)>,
    recoveries: Vec<(f64, usize)>,
    evictions: Vec<(u64, f64, EvictCause, f64, f64)>,
}

impl SimObserver for FaultAudit {
    fn on_node_failure(&mut self, t: f64, node: usize) {
        self.failures.push((t, node));
    }

    fn on_node_recovery(&mut self, t: f64, node: usize) {
        self.recoveries.push((t, node));
    }

    fn on_evict(
        &mut self,
        t: f64,
        job: &JobState,
        cause: EvictCause,
        lost_s: f64,
        penalty_s: f64,
    ) {
        self.evictions
            .push((job.spec.id, t, cause, lost_s, penalty_s));
    }
}

#[test]
fn scripted_node_failure_evicts_restores_and_recovers() {
    // pinned scenario: one long job on node 0 of a 2-node cluster;
    // node 0 dies at t=100 and comes back at t=400. The job must be
    // evicted with a checkpoint-restore penalty, resume on the healthy
    // node after its restore window, and still complete — slower than
    // the fault-free run by at least the restore penalty.
    let mut c = cfg(Policy::TLora, 1, 16);
    c.n_jobs = 1;
    let jobs = vec![long_job(0, 0.0, 8, 4, 50_000)];
    let baseline = simulate_jobs(&c, jobs.clone());
    assert_eq!(baseline.jct.len(), 1);
    assert!(
        baseline.jct[0].1 > 200.0,
        "job too short to be mid-run at the scripted failure: {}",
        baseline.jct[0].1
    );

    let script = vec![
        ScriptedFault {
            time: 100.0,
            kind: FaultKind::NodeFailure,
            target: 0,
        },
        ScriptedFault {
            time: 400.0,
            kind: FaultKind::NodeRecovery,
            target: 0,
        },
    ];
    let mut audit = FaultAudit::default();
    let faulted = simulate_jobs_with(
        &c,
        jobs,
        &EngineOptions {
            fault_script: script,
            ..EngineOptions::default()
        },
        &mut [&mut audit],
    );
    assert_eq!(faulted.jct.len(), 1, "job must survive the failure");
    assert!(faulted.incomplete_jobs.is_empty());
    assert_eq!(faulted.node_failures, 1);
    assert_eq!(faulted.restarts, 1);
    assert_eq!(faulted.preemptions, 0);
    assert_eq!(audit.failures, vec![(100.0, 0)]);
    assert_eq!(audit.recoveries, vec![(400.0, 0)]);
    assert_eq!(audit.evictions.len(), 1);
    let (id, t_evict, cause, lost_s, penalty_s) = audit.evictions[0];
    assert_eq!(id, 0);
    assert_eq!(t_evict, 100.0);
    assert_eq!(cause, EvictCause::NodeFailure);
    assert!(lost_s >= 0.0);
    // adapter-only restore: fixed overhead + checkpoint read
    assert!(
        penalty_s > 30.0 && penalty_s < 60.0,
        "restore penalty {penalty_s}"
    );
    assert_eq!(faulted.restore_delay_s, penalty_s);
    assert!(faulted.lost_step_time_s >= 0.0);
    // churn can only slow the job down, by at least the restore window
    assert!(
        faulted.jct[0].1 >= baseline.jct[0].1 + penalty_s - 1e-6,
        "faulted {} vs baseline {} + penalty {}",
        faulted.jct[0].1,
        baseline.jct[0].1,
        penalty_s
    );
    // goodput degrades, SLO bookkeeping stays in range
    assert!(faulted.goodput <= baseline.goodput);
    assert!((0.0..=1.0).contains(&faulted.slo_attainment));
}

#[test]
fn scripted_preemption_is_charged_and_survivable() {
    // two jobs sharing a 2-node cluster; job 0 is preempted mid-run.
    // It must pay one restore penalty, requeue, and still finish; a
    // preemption aimed at an already-finished job is a no-op.
    let mut c = cfg(Policy::TLora, 2, 16);
    c.n_jobs = 2;
    let jobs = vec![
        long_job(0, 0.0, 8, 4, 20_000),
        long_job(1, 0.0, 4, 2, 20_000),
    ];
    let script = vec![
        ScriptedFault {
            time: 50.0,
            kind: FaultKind::Preemption,
            target: 0,
        },
        // far beyond both completions: must be a silent no-op
        ScriptedFault {
            time: 9.0e6,
            kind: FaultKind::Preemption,
            target: 1,
        },
    ];
    let mut audit = FaultAudit::default();
    let r = simulate_jobs_with(
        &c,
        jobs,
        &EngineOptions {
            fault_script: script,
            ..EngineOptions::default()
        },
        &mut [&mut audit],
    );
    assert_eq!(completion_ids(&r), vec![0, 1]);
    assert_eq!(r.preemptions, 1);
    assert_eq!(r.restarts, 1);
    assert_eq!(r.node_failures, 0);
    assert_eq!(audit.evictions.len(), 1);
    assert_eq!(audit.evictions[0].0, 0);
    assert_eq!(audit.evictions[0].2, EvictCause::Preemption);
    assert!(r.restore_delay_s > 0.0);
}

#[test]
fn deterministic_with_faults_enabled() {
    // seeded MTBF churn + preemptions must stay a pure function of the
    // config — the sweep engine's cross-thread contract extends to the
    // fault dimension
    let mut c = cfg(Policy::TLora, 30, 32);
    c.faults.mtbf_s = 2_000.0;
    c.faults.mttr_s = 300.0;
    c.faults.preempt_rate = 1.0 / 4_000.0;
    let a = simulate(&c);
    let b = simulate(&c);
    assert_eq!(a.jct, b.jct);
    assert_eq!(a.sched_rounds, b.sched_rounds);
    assert_eq!(a.events, b.events);
    assert_eq!(a.node_failures, b.node_failures);
    assert_eq!(a.preemptions, b.preemptions);
    assert_eq!(a.restarts, b.restarts);
    assert!(a.lost_step_time_s == b.lost_step_time_s);
    assert!(a.restore_delay_s == b.restore_delay_s);
    assert!(a.goodput == b.goodput);
    assert!(a.slo_attainment == b.slo_attainment);
    // and the churn actually happened, so the comparison has teeth
    assert!(a.node_failures > 0, "scenario produced no failures");
    assert_eq!(a.jct.len() + a.incomplete_jobs.len(), c.n_jobs);
}

#[test]
fn tlora_goodput_under_churn_beats_megatron_isolation() {
    // the pinned churn scenario of the acceptance criteria. 8
    // "holder" jobs fill node 0; 8 smaller "visitor" jobs run on node
    // 1 until it dies at t=100 (permanently). Megatron restarts
    // evicted jobs in isolation: the visitors strand in the queue
    // until the holders drain node 0, then pay their full solo cost.
    // tLoRA re-fuses them elastically into the surviving groups,
    // where a rider's marginal step cost is far below its solo cost
    // (the planner's GEMM-efficiency saturation: more tokens per
    // fused step amortize the fixed waves), so useful samples keep
    // flowing through the outage and the cluster drains sooner —
    // strictly higher goodput.
    let mk_job = |id: u64,
                  submit: f64,
                  rank: usize,
                  batch: usize,
                  steps: u64| JobSpec {
        id,
        base_model: "llama3-8b".into(),
        rank,
        batch_size: batch,
        seq_len: 512,
        gpus: 1,
        total_steps: steps,
        submit_time: submit,
        max_slowdown: 1.5,
    };
    let mut jobs: Vec<JobSpec> = (0..8)
        .map(|i| mk_job(i, 0.0, 8, 4, 20_000))
        .collect();
    jobs.extend((8..16).map(|i| mk_job(i, 0.5, 4, 2, 10_000)));
    let script = vec![ScriptedFault {
        time: 100.0,
        kind: FaultKind::NodeFailure,
        target: 1,
    }];
    let run = |policy: Policy| {
        let mut c = cfg(policy, 16, 16);
        c.n_jobs = 16;
        simulate_jobs_with(
            &c,
            jobs.clone(),
            &EngineOptions {
                fault_script: script.clone(),
                ..EngineOptions::default()
            },
            &mut [],
        )
    };
    let r_t = run(Policy::TLora);
    let r_mg = run(Policy::Megatron);
    assert_eq!(
        r_t.jct.len(),
        16,
        "tLoRA left work undone: {:?}",
        r_t.incomplete_jobs
    );
    assert_eq!(
        r_mg.jct.len(),
        16,
        "Megatron left work undone: {:?}",
        r_mg.incomplete_jobs
    );
    // both policies lost node 1 and its 8 visitors
    assert_eq!(r_t.node_failures, 1);
    assert_eq!(r_mg.node_failures, 1);
    assert!(r_t.restarts >= 8 && r_mg.restarts >= 8);
    assert!(
        r_t.goodput > r_mg.goodput,
        "tLoRA goodput {} vs Megatron {} under churn \
         (makespan {} vs {}, restarts {} vs {})",
        r_t.goodput,
        r_mg.goodput,
        r_t.makespan,
        r_mg.makespan,
        r_t.restarts,
        r_mg.restarts
    );
    // and nobody's SLO story got worse for it
    assert!(r_t.slo_attainment >= r_mg.slo_attainment - 1e-12);
}

#[test]
fn unsatisfiable_job_is_reported_incomplete_not_dropped() {
    // a job asking for more GPUs than the cluster has can never run;
    // the old loop spun to its t_max valve and silently dropped it
    // from jct — the engine must terminate promptly and name it
    let mut c = cfg(Policy::TLora, 2, 16);
    let ok = long_job(0, 0.0, 8, 4, 200);
    let impossible = JobSpec {
        gpus: 64, // > 16 available: can never own an allocation
        // different backbone: cannot be elastically absorbed either
        base_model: "qwen3-8b".into(),
        ..long_job(1, 0.0, 8, 4, 200)
    };
    c.n_jobs = 2;
    let r = simulate_jobs(&c, vec![ok, impossible]);
    assert_eq!(completion_ids(&r), vec![0]);
    assert_eq!(r.incomplete_jobs, vec![1]);
    // prompt exit: no per-horizon spinning toward the 1e7 s valve
    assert!(
        r.sched_rounds < 200,
        "engine spun {} rounds on a dead queue",
        r.sched_rounds
    );
    assert!(r.makespan < 1e6, "makespan {} ran to the valve", r.makespan);
}
