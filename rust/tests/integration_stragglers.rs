//! Pinned straggler scenarios: the detection-vs-oblivious axis.
//!
//! The setup isolates exactly the mechanism the straggler subsystem
//! exists to measure. Eight identical 1-GPU jobs submit at t=0 on a
//! 2-node × 8-GPU cluster; the best-fit allocator packs all of them
//! onto node 0 and tLoRA fuses them there, leaving node 1 idle. A
//! scripted degrade then drops node 0 to 0.15× mid-trace and never
//! restores it:
//!
//! * **detection-enabled tLoRA** watches observed step times drift to
//!   ~6.7× plan, crosses the migrate threshold, evicts the jobs off
//!   node 0 (paying the checkpoint-restore cost) and re-places them on
//!   the idle healthy node — finishing close to the no-straggler
//!   makespan;
//! * **detection-disabled tLoRA** (same policy, `stragglers.detect =
//!   false`) has no estimator, so every job crawls at 0.15× to the
//!   end.
//!
//! The scenario is self-calibrating: the degrade instant is 30% of the
//! *measured* healthy makespan, and the SLO factor is chosen between
//! the two arms' measured completion spreads (the SLO factor only
//! affects reporting, never scheduling, so probe runs and final runs
//! share identical dynamics). The margins are deliberately enormous
//! (≈4× between the arms) so the assertions pin the mechanism, not the
//! cost model's third digit.

use tlora::config::{ExperimentConfig, Policy};
use tlora::sim::{simulate_jobs_with, EngineOptions, SimResult};
use tlora::workload::faults::ScriptedStraggler;
use tlora::workload::JobSpec;

const N_JOBS: u64 = 8;
const STEPS: u64 = 600;
const SLOW: f64 = 0.15;

fn jobs() -> Vec<JobSpec> {
    (0..N_JOBS)
        .map(|id| JobSpec {
            id,
            base_model: "llama3-8b".into(),
            rank: 8,
            batch_size: 4,
            seq_len: 512,
            gpus: 1,
            total_steps: STEPS,
            submit_time: 0.0,
            max_slowdown: 2.0,
        })
        .collect()
}

fn scenario_cfg(detect: bool, slo_factor: f64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.policy = Policy::TLora;
    cfg.cluster = tlora::cluster::ClusterSpec::with_gpus(16);
    cfg.n_jobs = N_JOBS as usize;
    cfg.seed = 7;
    // finer reschedule cadence: detection can only act at rounds
    cfg.scheduler.horizon_s = 30.0;
    // migration cost is real but small relative to the crawl it avoids
    cfg.faults.restore_overhead_s = 2.0;
    cfg.faults.ckpt_read_bw = 1.0e12;
    cfg.faults.slo_factor = slo_factor;
    cfg.stragglers.detect = detect;
    cfg.stragglers.detect_alpha = 0.3;
    cfg.stragglers.detect_threshold = 1.2;
    cfg.stragglers.migrate_threshold = 1.4;
    cfg
}

/// Run one arm. `aimd_settle_obs = u64::MAX` keeps the AIMD pressure
/// (and therefore the `horizon_s` reschedule cadence) alive for the
/// whole run in *both* arms, so the detection arm's extra rounds come
/// from detection semantics, not from a different cadence.
fn run_arm(
    detect: bool,
    slo_factor: f64,
    script: Vec<ScriptedStraggler>,
) -> SimResult {
    let opts = EngineOptions {
        aimd_settle_obs: u64::MAX,
        straggler_script: script,
        ..EngineOptions::default()
    };
    simulate_jobs_with(
        &scenario_cfg(detect, slo_factor),
        jobs(),
        &opts,
        &mut [],
    )
}

fn max_jct(r: &SimResult) -> f64 {
    r.jct.iter().map(|&(_, v)| v).fold(0.0, f64::max)
}

fn min_jct(r: &SimResult) -> f64 {
    r.jct
        .iter()
        .map(|&(_, v)| v)
        .fold(f64::INFINITY, f64::min)
}

#[test]
fn detection_beats_oblivious_on_goodput_and_slo() {
    // healthy reference: no straggler → both arms identical dynamics
    let healthy = run_arm(true, 3.0, vec![]);
    assert_eq!(healthy.jct.len(), N_JOBS as usize);
    assert_eq!(healthy.migrations, 0);
    let t0 = healthy.makespan;
    assert!(t0 > 0.0 && t0.is_finite());

    // node 0 drops to 0.15x at 30% of the healthy makespan, for good
    let script = vec![ScriptedStraggler {
        time: 0.3 * t0,
        node: 0,
        speed: SLOW,
    }];

    let detect = run_arm(true, 3.0, script.clone());
    let oblivious = run_arm(false, 3.0, script.clone());

    // both arms finish every job and saw the same degrade
    for (name, r) in [("detect", &detect), ("oblivious", &oblivious)]
    {
        assert_eq!(r.jct.len(), N_JOBS as usize, "{name}");
        assert!(r.incomplete_jobs.is_empty(), "{name}");
        assert_eq!(r.node_degrades, 1, "{name}");
        assert!(r.degraded_node_time_s > 0.0, "{name}");
    }
    // only the detection arm migrates; the oblivious arm cannot
    assert!(detect.migrations > 0, "detection never migrated");
    assert_eq!(oblivious.migrations, 0);

    // every detected job strictly beats every oblivious job: the
    // oblivious arm crawls the final 70% of the work at 0.15x
    assert!(
        max_jct(&detect) < min_jct(&oblivious),
        "detection worst JCT {} >= oblivious best JCT {}",
        max_jct(&detect),
        min_jct(&oblivious)
    );

    // strictly better goodput (same useful samples, smaller makespan)
    assert!(
        detect.goodput > oblivious.goodput,
        "goodput: detect {} vs oblivious {}",
        detect.goodput,
        oblivious.goodput
    );

    // SLO attainment: place the deadline in the (wide) gap between
    // the arms. slo_factor only affects reporting, so re-running with
    // the calibrated factor reproduces identical dynamics.
    let solo = {
        let mut cfg = scenario_cfg(true, 3.0);
        cfg.policy = Policy::Megatron;
        cfg.n_jobs = 1;
        simulate_jobs_with(
            &cfg,
            jobs().into_iter().take(1).collect(),
            &EngineOptions::default(),
            &mut [],
        )
    };
    assert_eq!(solo.jct.len(), 1);
    let ideal = solo.jct[0].1; // ≈ total_steps × iso step time
    let mid = 0.5 * (max_jct(&detect) + min_jct(&oblivious));
    // deadline_j = slo_factor × Δ^max × steps × iso ≈ slo_factor × 2 × ideal
    let slo_factor = mid / (2.0 * ideal);
    let detect2 = run_arm(true, slo_factor, script.clone());
    let oblivious2 = run_arm(false, slo_factor, script.clone());
    assert_eq!(detect2.jct, detect.jct, "slo_factor changed dynamics");
    assert_eq!(oblivious2.jct, oblivious.jct);
    assert!(
        detect2.slo_attainment > oblivious2.slo_attainment,
        "SLO: detect {} vs oblivious {}",
        detect2.slo_attainment,
        oblivious2.slo_attainment
    );
    assert!(detect2.slo_attainment >= 0.5, "detection arm mostly late");
    assert!(
        oblivious2.slo_attainment <= 0.5,
        "oblivious arm mostly on time"
    );

    // both arms are deterministic: bit-identical reruns
    let detect_again = run_arm(true, 3.0, script.clone());
    let oblivious_again = run_arm(false, 3.0, script);
    assert_eq!(detect.jct, detect_again.jct);
    assert_eq!(detect.migrations, detect_again.migrations);
    assert!(detect.goodput == detect_again.goodput);
    assert_eq!(oblivious.jct, oblivious_again.jct);
    assert!(oblivious.goodput == oblivious_again.goodput);
}

#[test]
fn seeded_straggler_sweep_canonical_json_identical_threads_1_vs_8() {
    // the sweep-level determinism contract for the degraded-node axis:
    // canonical JSON bytes are a pure function of the grid whatever
    // the worker count (the scripted pinned scenario above cannot ride
    // the sweep path, so this uses the seeded model via --stragglers)
    use tlora::sweep::{run, to_json_canonical, SweepGrid};
    let mut g = SweepGrid::default();
    g.policies = vec![Policy::TLora, Policy::Megatron];
    g.n_jobs = vec![10];
    g.gpus = vec![16];
    g.rate_scales = vec![2.0];
    g.months = vec![1];
    g.stragglers = vec![0.0, 600.0];
    g.seeds = vec![7, 8];
    let serial = run(&g, 1).unwrap();
    let parallel = run(&g, 8).unwrap();
    let canon = to_json_canonical(&serial).to_pretty();
    let canon_par = to_json_canonical(&parallel).to_pretty();
    if canon != canon_par {
        panic!(
            "degraded-node canonical sweep JSON differs between \
             --threads 1 and 8; first divergence at {}",
            tlora::util::json::diff(&canon, &canon_par)
                .map(|d| d.to_string())
                .unwrap_or_else(|| "formatting drift".into())
        );
    }
    // and the degraded cells actually saw episodes
    let parsed = tlora::util::json::parse(&canon).unwrap();
    let mut degrades = 0i64;
    for p in parsed.get("points").unwrap().as_arr().unwrap() {
        let mtbs =
            p.get("straggler_mtbs_s").unwrap().as_f64().unwrap();
        let nd = p.get("node_degrades").unwrap().as_i64().unwrap();
        if mtbs == 0.0 {
            assert_eq!(nd, 0, "degrades in a straggler-free cell");
        } else {
            degrades += nd;
        }
    }
    assert!(degrades > 0, "no straggler cell saw a single episode");
}
