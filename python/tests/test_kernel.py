"""L1 correctness: fused Pallas LoRA kernel vs the pure-jnp oracle.

This is the core numerics signal of the stack: everything the Rust runtime
executes flows through these kernels. Hypothesis sweeps shapes, adapter
counts, heterogeneous ranks, dtypes and tile boundaries; explicit tests
pin the paper-relevant edge cases.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.fused_lora import (
    fused_lora, fused_lora_fwd_only, fused_lora_bwd_only, unfused_lora,
    vmem_footprint_bytes, mxu_utilization_estimate)
from compile.kernels.ref import lora_ref, lora_ref_grads

jax.config.update("jax_enable_x64", False)


def _mk(t, d, o, k_adp, r_max, ranks, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (t, d), dtype)
    aid = jax.random.randint(ks[1], (t,), 0, k_adp).astype(jnp.int32)
    a = jax.random.normal(ks[2], (k_adp, d, r_max), dtype) * 0.3
    b = jax.random.normal(ks[3], (k_adp, r_max, o), dtype) * 0.3
    # zero-pad past each adapter's true rank (heterogeneous ranks)
    rr = jnp.arange(r_max)
    mask = (rr[None, :] < jnp.asarray(ranks)[:, None]).astype(dtype)
    a = a * mask[:, None, :]
    b = b * mask[:, :, None]
    scaling = jnp.asarray([16.0 / r for r in ranks], jnp.float32)
    return x, aid, a, b, scaling


class TestForward:
    def test_basic_matches_ref(self):
        x, aid, a, b, s = _mk(96, 32, 48, 3, 8, (2, 4, 8))
        got = fused_lora_fwd_only(x, aid, a, b, s, tile_t=32)
        want = lora_ref(x, aid, a, b, s)
        np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)

    def test_matches_unfused(self):
        x, aid, a, b, s = _mk(64, 16, 16, 4, 4, (1, 2, 3, 4))
        got = fused_lora_fwd_only(x, aid, a, b, s, tile_t=32)
        want = unfused_lora(x, aid, a, b, s)
        np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)

    def test_tokens_not_multiple_of_tile(self):
        # T=50 with tile 32 forces internal padding
        x, aid, a, b, s = _mk(50, 16, 24, 2, 4, (2, 4))
        got = fused_lora_fwd_only(x, aid, a, b, s, tile_t=32)
        want = lora_ref(x, aid, a, b, s)
        assert got.shape == (50, 24)
        np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)

    def test_out_of_range_ids_contribute_zero(self):
        x, aid, a, b, s = _mk(64, 16, 16, 2, 4, (4, 4))
        aid = aid.at[:16].set(-1)           # padding tokens
        got = fused_lora_fwd_only(x, aid, a, b, s, tile_t=32)
        assert jnp.allclose(got[:16], 0.0)
        want = lora_ref(x, aid, a, b, s)
        np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)

    def test_single_adapter(self):
        x, aid, a, b, s = _mk(32, 8, 8, 1, 2, (2,))
        got = fused_lora_fwd_only(x, aid, a, b, s, tile_t=32)
        want = lora_ref(x, aid, a, b, s)
        np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)

    def test_adapter_with_no_tokens(self):
        x, aid, a, b, s = _mk(64, 16, 16, 3, 4, (2, 2, 4))
        aid = jnp.zeros_like(aid)           # all tokens -> adapter 0
        got = fused_lora_fwd_only(x, aid, a, b, s, tile_t=32)
        want = lora_ref(x, aid, a, b, s)
        np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)

    def test_bf16_inputs_f32_accumulate(self):
        x, aid, a, b, s = _mk(64, 32, 32, 2, 8, (4, 8), dtype=jnp.bfloat16)
        got = fused_lora_fwd_only(x, aid, a, b, s, tile_t=32)
        want = lora_ref(x, aid, a, b, s)
        assert got.dtype == jnp.bfloat16
        np.testing.assert_allclose(
            got.astype(jnp.float32), want.astype(jnp.float32),
            atol=0.15, rtol=0.15)

    def test_scaling_applied(self):
        x, aid, a, b, _ = _mk(32, 8, 8, 2, 4, (4, 4))
        s1 = jnp.asarray([1.0, 1.0], jnp.float32)
        s2 = jnp.asarray([2.0, 0.5], jnp.float32)
        y1 = fused_lora_fwd_only(x, aid, a, b, s1, tile_t=32)
        y2 = fused_lora_fwd_only(x, aid, a, b, s2, tile_t=32)
        m0 = (aid == 0)
        np.testing.assert_allclose(y2[m0], 2.0 * y1[m0], atol=1e-4,
                                   rtol=1e-4)
        np.testing.assert_allclose(y2[~m0], 0.5 * y1[~m0], atol=1e-4,
                                   rtol=1e-4)


class TestBackward:
    def test_bwd_matches_closed_form(self):
        x, aid, a, b, s = _mk(96, 24, 32, 3, 8, (2, 4, 8), seed=7)
        g = jax.random.normal(jax.random.PRNGKey(9), (96, 32))
        dx, da, db = fused_lora_bwd_only(x, aid, a, b, s, g, tile_t=32)
        rdx, rda, rdb = lora_ref_grads(x, aid, a, b, s, g)
        np.testing.assert_allclose(dx, rdx, atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(da, rda, atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(db, rdb, atol=1e-4, rtol=1e-4)

    def test_custom_vjp_matches_autodiff_of_ref(self):
        x, aid, a, b, s = _mk(64, 16, 16, 2, 4, (2, 4), seed=3)

        def loss_fused(params):
            aa, bb = params
            y = fused_lora(x, aid, aa, bb, s, 32)
            return jnp.sum(jnp.sin(y))

        def loss_ref(params):
            aa, bb = params
            y = lora_ref(x, aid, aa, bb, s)
            return jnp.sum(jnp.sin(y))

        gf = jax.grad(loss_fused)((a, b))
        gr = jax.grad(loss_ref)((a, b))
        np.testing.assert_allclose(gf[0], gr[0], atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(gf[1], gr[1], atol=1e-4, rtol=1e-4)

    def test_dx_flows(self):
        x, aid, a, b, s = _mk(64, 16, 16, 2, 4, (2, 4), seed=5)

        def lf(xx):
            return jnp.sum(fused_lora(xx, aid, a, b, s, 32) ** 2)

        def lr(xx):
            return jnp.sum(lora_ref(xx, aid, a, b, s) ** 2)

        np.testing.assert_allclose(jax.grad(lf)(x), jax.grad(lr)(x),
                                   atol=1e-3, rtol=1e-3)

    def test_padded_rank_gradients_are_zero(self):
        """The invariant that makes heterogeneous ranks exact: grads in
        the zero-padded region vanish, so padding survives training."""
        x, aid, a, b, s = _mk(64, 16, 16, 2, 8, (2, 4), seed=11)

        def loss(params):
            aa, bb = params
            return jnp.sum(fused_lora(x, aid, aa, bb, s, 32) ** 2)

        da, db = jax.grad(loss)((a, b))
        assert jnp.allclose(da[0][:, 2:], 0.0)   # adapter 0: rank 2
        assert jnp.allclose(db[0][2:, :], 0.0)
        assert jnp.allclose(da[1][:, 4:], 0.0)   # adapter 1: rank 4
        assert jnp.allclose(db[1][4:, :], 0.0)


class TestHypothesis:
    @settings(max_examples=20, deadline=None)
    @given(
        t=st.integers(min_value=1, max_value=130),
        d=st.sampled_from([8, 16, 32]),
        o=st.sampled_from([8, 16, 24]),
        k_adp=st.integers(min_value=1, max_value=5),
        seed=st.integers(min_value=0, max_value=2 ** 16),
        tile=st.sampled_from([16, 32, 128]),
        data=st.data(),
    )
    def test_fwd_random(self, t, d, o, k_adp, seed, tile, data):
        r_max = 8
        ranks = tuple(
            data.draw(st.lists(st.integers(1, r_max), min_size=k_adp,
                               max_size=k_adp)))
        x, aid, a, b, s = _mk(t, d, o, k_adp, r_max, ranks, seed=seed)
        got = fused_lora_fwd_only(x, aid, a, b, s, tile_t=tile)
        want = lora_ref(x, aid, a, b, s)
        np.testing.assert_allclose(got, want, atol=2e-4, rtol=2e-4)

    @settings(max_examples=12, deadline=None)
    @given(
        t=st.integers(min_value=1, max_value=96),
        d=st.sampled_from([8, 16]),
        k_adp=st.integers(min_value=1, max_value=4),
        seed=st.integers(min_value=0, max_value=2 ** 16),
        data=st.data(),
    )
    def test_bwd_random(self, t, d, k_adp, seed, data):
        r_max = 4
        ranks = tuple(
            data.draw(st.lists(st.integers(1, r_max), min_size=k_adp,
                               max_size=k_adp)))
        x, aid, a, b, s = _mk(t, d, d, k_adp, r_max, ranks, seed=seed)
        g = jax.random.normal(jax.random.PRNGKey(seed + 1), (t, d))
        dx, da, db = fused_lora_bwd_only(x, aid, a, b, s, g, tile_t=32)
        rdx, rda, rdb = lora_ref_grads(x, aid, a, b, s, g)
        np.testing.assert_allclose(dx, rdx, atol=3e-4, rtol=3e-4)
        np.testing.assert_allclose(da, rda, atol=3e-4, rtol=3e-4)
        np.testing.assert_allclose(db, rdb, atol=3e-4, rtol=3e-4)


class TestOracleSelfConsistency:
    def test_ref_grads_match_autodiff(self):
        x, aid, a, b, s = _mk(48, 12, 20, 3, 4, (1, 2, 4), seed=13)
        g = jax.random.normal(jax.random.PRNGKey(17), (48, 20))

        def inner(xx, aa, bb):
            return jnp.sum(lora_ref(xx, aid, aa, bb, s) * g)

        adx, ada, adb = jax.grad(inner, argnums=(0, 1, 2))(x, a, b)
        rdx, rda, rdb = lora_ref_grads(x, aid, a, b, s, g)
        np.testing.assert_allclose(adx, rdx, atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(ada, rda, atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(adb, rdb, atol=1e-4, rtol=1e-4)


class TestPerfModels:
    def test_vmem_footprint_within_budget(self):
        # paper-scale tile on an 8B model's projection: must fit 16 MB VMEM
        bytes_ = vmem_footprint_bytes(128, 4096, 16, 4096)
        assert bytes_ < 16 * 2 ** 20

    def test_vmem_monotone_in_tile(self):
        a = vmem_footprint_bytes(64, 256, 8, 256)
        b = vmem_footprint_bytes(128, 256, 8, 256)
        assert b > a

    def test_mxu_utilization_bounds(self):
        u = mxu_utilization_estimate([100, 100], 256, [16, 16], 16, 256)
        assert 0.0 < u <= 1.0
        # uniform full-rank tokens across K=2 adapters: each pass wastes
        # the other adapter's tokens -> utilization 1/K
        assert abs(u - 0.5) < 1e-9

    def test_mxu_utilization_rank_padding(self):
        full = mxu_utilization_estimate([64], 128, [16], 16, 128)
        padded = mxu_utilization_estimate([64], 128, [2], 16, 128)
        assert padded < full
