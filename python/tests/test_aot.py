"""AOT pipeline: manifest structure, HLO text sanity, determinism."""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))
PYDIR = os.path.join(REPO, "python")


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
         "--variants", "tiny", "--skip-kmicro", "--skip-nano"],
        cwd=PYDIR, capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr
    return out


def test_manifest_structure(built):
    m = json.load(open(built / "manifest.json"))
    assert m["format"] == 1
    names = [v["name"] for v in m["variants"]]
    assert "tiny" in names
    v = m["variants"][names.index("tiny")]
    assert v["init"]["inputs"][0]["dtype"] == "i32"
    n_state = len(v["init"]["outputs"])
    # backbone(10) + lora(4) + m(4) + v(4) + t(1)
    assert n_state == 23
    assert len(v["step"]["inputs"]) == n_state + 2
    # lora(4) + m(4) + v(4) + t + loss + per_adapter
    assert len(v["step"]["outputs"]) == 15


def test_hlo_files_exist_and_are_text(built):
    m = json.load(open(built / "manifest.json"))
    for v in m["variants"]:
        for prog in ("init", "step"):
            txt = open(built / v[prog]["file"]).read()
            assert txt.startswith("HloModule"), txt[:40]
            assert "ENTRY" in txt


def test_step_io_shapes_consistent(built):
    m = json.load(open(built / "manifest.json"))
    v = m["variants"][0]
    cfg = v["config"]
    tok = v["step"]["inputs"][-2]
    aid = v["step"]["inputs"][-1]
    total_b = sum(cfg["batch_sizes"])
    assert tok["shape"] == [total_b, cfg["seq_len"]]
    assert aid["shape"] == [total_b]
    per = v["step"]["outputs"][-1]
    assert per["shape"] == [cfg["num_adapters"]]
    loss = v["step"]["outputs"][-2]
    assert loss["shape"] == []


def test_lora_state_shapes(built):
    m = json.load(open(built / "manifest.json"))
    v = m["variants"][0]
    cfg = v["config"]
    # lora leaves follow the 10 backbone leaves
    a_q = v["init"]["outputs"][10]
    assert a_q["shape"] == [cfg["n_layers"], cfg["num_adapters"],
                            cfg["d_model"], cfg["r_max"]]


def test_deterministic_lowering(built, tmp_path):
    """Same variant lowered twice gives identical HLO text."""
    out2 = tmp_path / "again"
    out2.mkdir()
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out2),
         "--variants", "tiny", "--skip-kmicro", "--skip-nano"],
        cwd=PYDIR, capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr
    a = open(built / "tiny.step.hlo.txt").read()
    b = open(out2 / "tiny.step.hlo.txt").read()
    assert a == b
