"""L2 correctness: the Shared Super-Model training step.

Validates the SSM's functional-equivalence claims from §3.2: fused
execution preserves independent-training semantics — per-job parameter
isolation, rank-padding invariance, fused == unfused numerics — and that
the step actually learns (loss decreases on a memorizable stream).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (SsmConfig, init_fn, ssm_forward, loss_fn,
                           train_step, train_step_nano, flatten_state,
                           unflatten_state, make_flat_train_step,
                           make_flat_init)

CFG = SsmConfig(name="test", vocab=64, d_model=32, n_layers=2, n_heads=4,
                d_ff=64, seq_len=16, num_adapters=3, r_max=4,
                ranks=(1, 2, 4), batch_sizes=(2, 2, 2), tile_t=32, lr=5e-3)


def _data(cfg, seed=0):
    key = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(
        key, (cfg.total_batch, cfg.seq_len), 0, cfg.vocab).astype(jnp.int32)
    aid = jnp.repeat(jnp.arange(cfg.num_adapters, dtype=jnp.int32),
                     jnp.asarray(cfg.batch_sizes))
    return tokens, aid


class TestForward:
    def test_shapes(self):
        backbone, lora, _ = init_fn(CFG, 0)
        tokens, aid = _data(CFG)
        logits = ssm_forward(CFG, backbone, lora, tokens, aid)
        assert logits.shape == (CFG.total_batch, CFG.seq_len, CFG.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_fused_equals_unfused(self):
        """Fig. 7's two kernel paths are numerically identical."""
        cfg_f = CFG
        cfg_u = dataclasses.replace(CFG, fused=False)
        backbone, lora, _ = init_fn(CFG, 0)
        # B=0 makes LoRA delta zero; perturb B to make the test sharp
        lora = jax.tree.map(
            lambda x: x + 0.01 * jax.random.normal(
                jax.random.PRNGKey(1), x.shape), lora)
        tokens, aid = _data(CFG)
        lf = ssm_forward(cfg_f, backbone, lora, tokens, aid)
        lu = ssm_forward(cfg_u, backbone, lora, tokens, aid)
        np.testing.assert_allclose(lf, lu, atol=1e-4, rtol=1e-4)

    def test_zero_lora_b_means_backbone_only(self):
        backbone, lora, _ = init_fn(CFG, 0)   # B init to zero
        tokens, aid = _data(CFG)
        out1 = ssm_forward(CFG, backbone, lora, tokens, aid)
        out2 = ssm_forward(CFG, backbone, lora, tokens,
                           jnp.zeros_like(aid))   # different ownership
        np.testing.assert_allclose(out1, out2, atol=1e-5)


class TestTrainStep:
    def test_loss_decreases(self):
        backbone, lora, opt = init_fn(CFG, 0)
        tokens, aid = _data(CFG)
        step = jax.jit(lambda lo, op: train_step(
            CFG, backbone, lo, op, tokens, aid))
        first = None
        for i in range(12):
            lora, opt, loss, _ = step(lora, opt)
            if first is None:
                first = float(loss)
        assert float(loss) < first - 0.01, (first, float(loss))

    def test_backbone_never_changes(self):
        # train_step signature takes backbone immutably; verify the flat
        # program returns no backbone outputs (structure-level freeze).
        flat = make_flat_train_step(CFG)
        backbone, lora, opt = init_fn(CFG, 0)
        tokens, aid = _data(CFG)
        args = flatten_state(backbone, lora, opt) + [tokens, aid]
        outs = flat(*args)
        # outputs: 4 lora + 4 m + 4 v + t + loss + per_adapter
        assert len(outs) == 4 * 3 + 1 + 2

    def test_per_job_isolation(self):
        """§3.2: a job whose tokens are absent must see *zero* update to
        its adapter and optimizer slice — grouped training is lossless."""
        backbone, lora, opt = init_fn(CFG, 0)
        tokens, aid = _data(CFG)
        aid = jnp.where(aid == 2, 0, aid)     # adapter 2 gets no tokens
        lora2, opt2, _, _ = train_step(CFG, backbone, lora, opt, tokens, aid)
        for name in lora:
            np.testing.assert_allclose(lora2[name][:, 2], lora[name][:, 2],
                                       atol=0, rtol=0)
            np.testing.assert_allclose(opt2["m"][name][:, 2], 0.0, atol=0)

    def test_rank_padding_preserved(self):
        """Zero-padded rank region stays exactly zero through Adam."""
        backbone, lora, opt = init_fn(CFG, 0)
        tokens, aid = _data(CFG)
        for _ in range(3):
            lora, opt, _, _ = train_step(CFG, backbone, lora, opt, tokens,
                                         aid)
        # adapter 0 has rank 1, adapter 1 rank 2 (r_max 4)
        assert bool(jnp.all(lora["a_q"][:, 0, :, 1:] == 0.0))
        assert bool(jnp.all(lora["b_q"][:, 0, 1:, :] == 0.0))
        assert bool(jnp.all(lora["a_v"][:, 1, :, 2:] == 0.0))
        assert bool(jnp.all(lora["b_v"][:, 1, 2:, :] == 0.0))

    def test_per_adapter_loss_shape_and_finite(self):
        backbone, lora, opt = init_fn(CFG, 0)
        tokens, aid = _data(CFG)
        _, _, loss, per = train_step(CFG, backbone, lora, opt, tokens, aid)
        assert per.shape == (CFG.num_adapters,)
        assert bool(jnp.all(jnp.isfinite(per)))
        # mean of per-adapter losses weighted by batch share == total
        w = jnp.asarray(CFG.batch_sizes) / CFG.total_batch
        np.testing.assert_allclose(float(jnp.sum(per * w)), float(loss),
                                   atol=1e-5)

    def test_grouped_equals_isolated_training(self):
        """The SSM headline guarantee: training K jobs fused produces the
        same adapter trajectories as training each job alone."""
        backbone, lora, opt = init_fn(CFG, 0)
        tokens, aid = _data(CFG)
        fused_lora_p, fused_opt, _, _ = train_step(
            CFG, backbone, lora, opt, tokens, aid)
        for k in range(CFG.num_adapters):
            sel = aid == k
            tk = tokens[sel]
            # run the same SSM with only job k's sequences present
            aid_k = jnp.full((tk.shape[0],), k, jnp.int32)
            solo_lora, _, _, _ = train_step(
                dataclasses.replace(CFG, batch_sizes=(int(sel.sum()),)),
                backbone, lora, opt, tk, aid_k)
            for name in lora:
                np.testing.assert_allclose(
                    solo_lora[name][:, k], fused_lora_p[name][:, k],
                    atol=2e-6, rtol=2e-5)


class TestNanoBatching:
    def test_nano_grad_equivalence(self):
        """Composition-balanced nano-batches reproduce the full-batch
        update (the coordinator round-robins jobs across nano slices)."""
        backbone, lora, opt = init_fn(CFG, 0)
        tokens, _ = _data(CFG)
        # round-robin layout: [0,1,2, 0,1,2] -> each nano slice of size 3
        # contains one sequence of every job
        aid = jnp.tile(jnp.arange(CFG.num_adapters, dtype=jnp.int32), 2)
        l1, o1, loss1, _ = train_step(CFG, backbone, lora, opt, tokens, aid)
        l2, o2, loss2, _ = train_step_nano(CFG, backbone, lora, opt, tokens,
                                           aid, n_nano=2)
        # losses: mean-of-slice-means == overall mean for equal slices
        np.testing.assert_allclose(float(loss1), float(loss2), atol=1e-5)
        for name in l1:
            np.testing.assert_allclose(l1[name], l2[name], atol=1e-5,
                                       rtol=1e-4)

    def test_nano_sizes(self):
        backbone, lora, opt = init_fn(CFG, 0)
        tokens, aid = _data(CFG)
        for n in (1, 2, 3, 6):
            l, o, loss, per = train_step_nano(
                CFG, backbone, lora, opt, tokens, aid, n_nano=n)
            assert bool(jnp.isfinite(loss))


class TestFlattening:
    def test_roundtrip(self):
        backbone, lora, opt = init_fn(CFG, 3)
        flat = flatten_state(backbone, lora, opt)
        b2, l2, o2 = unflatten_state(CFG, flat)
        for n in backbone:
            np.testing.assert_array_equal(backbone[n], b2[n])
        for n in lora:
            np.testing.assert_array_equal(lora[n], l2[n])
        np.testing.assert_array_equal(opt["t"], o2["t"])

    def test_flat_init_matches_init_fn(self):
        flat_init = make_flat_init(CFG)
        flat = flat_init(7)
        backbone, lora, opt = init_fn(CFG, 7)
        ref_flat = flatten_state(backbone, lora, opt)
        assert len(flat) == len(ref_flat)
        for a, b in zip(flat, ref_flat):
            np.testing.assert_array_equal(a, b)

    def test_seed_changes_init(self):
        f = make_flat_init(CFG)
        a, b = f(0), f(1)
        assert not np.allclose(a[0], b[0])
