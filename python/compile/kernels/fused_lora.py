"""Fused batched multi-LoRA Pallas kernel (tLoRA §3.3, Layer 1).

The paper's Kernel Fuser executes K heterogeneous LoRA adapters over a
shared token stream in a *single* kernel launch, never materializing the
per-adapter dense update ``W_i = A_i @ B_i`` and never allocating
full-sized per-adapter temporaries.  For each adapter ``i`` the tokens
mapped to it are gathered, pushed through the down-projection ``A_i`` to a
compact ``(|X_i|, r_i)`` intermediate, immediately pushed through the
up-projection ``B_i`` and scattered back into the shared output.

Hardware adaptation (GPU -> TPU, see DESIGN.md §Hardware-Adaptation):

* Triton's per-CTA token gather becomes a Pallas grid over
  ``(token_tile, adapter)`` with ``BlockSpec`` describing the HBM->VMEM
  schedule.  Gather/scatter is expressed as a rank-mask multiply — exact,
  because a zeroed row contributes nothing to either GEMM.
* The rank-``r`` intermediate lives in VMEM scratch (``r_max <= 16`` for
  the paper's workloads, trivially resident).
* Heterogeneous ranks share one static shape ``r_max`` with zero-padded
  columns/rows.  Padding is *exactly* preserved by training: with
  ``A[:, r:] = 0`` and ``B[r:, :] = 0`` the corresponding gradients are
  identically zero (see ``python/tests/test_model.py``).
* MXU targeting: matmuls accumulate in f32 via ``preferred_element_type``
  so bf16 inputs hit the systolic array shape the paper's tensor-core
  path used.

All ``pallas_call``s use ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret mode lowers to plain HLO that
the Rust runtime executes (see /opt/xla-example/README.md).

Public API
----------

``fused_lora(x, adapter_ids, a, b, scaling)``
    Differentiable (``jax.custom_vjp``) fused multi-adapter LoRA delta.
``fused_lora_fwd_only`` / ``fused_lora_bwd_*``
    The raw forward / backward kernels (exported for tests).
``unfused_lora``
    The per-adapter "PyTorch-native" comparator used by the Fig. 7
    ablation: one masked dense GEMM pair per adapter, materializing the
    per-adapter temporaries the fused kernel avoids.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default token tile. On a real TPU this is the sublane-aligned HBM->VMEM
# block; the kernel_micro bench sweeps it (DESIGN.md §Perf).
DEFAULT_TILE_T = 128


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


# ---------------------------------------------------------------------------
# Forward kernel: grid (token_tiles, K); adapters iterate innermost so each
# output tile stays resident in VMEM while every adapter accumulates into it.
# ---------------------------------------------------------------------------


def _fwd_kernel(x_ref, aid_ref, a_ref, b_ref, s_ref, o_ref):
    k = pl.program_id(1)
    x = x_ref[...]                                        # (Tt, D)
    mask = (aid_ref[...] == k).astype(jnp.float32)[:, None]
    xm = x * mask.astype(x.dtype)                         # gather-by-mask
    # (Tt, r_max) compact intermediate — the tensor the paper keeps in
    # shared memory / VMEM instead of materializing A_i @ B_i.
    xa = jnp.dot(xm, a_ref[0], preferred_element_type=jnp.float32)
    y = jnp.dot(xa, b_ref[0].astype(jnp.float32),
                preferred_element_type=jnp.float32)
    y = y * s_ref[0]

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Rows of tokens not owned by adapter k are exactly zero (mask applied
    # to x), so accumulation doubles as the scatter.
    o_ref[...] += y.astype(o_ref.dtype)


def fused_lora_fwd_only(x, adapter_ids, a, b, scaling, *,
                        tile_t: int = DEFAULT_TILE_T):
    """Forward fused LoRA delta.

    Args:
      x:           (T, D) token activations.
      adapter_ids: (T,) int32 adapter ownership per token. Tokens with ids
                   outside [0, K) (e.g. -1 padding) contribute zero.
      a:           (K, D, R) stacked down-projections, zero-padded past r_i.
      b:           (K, R, O) stacked up-projections, zero-padded past r_i.
      scaling:     (K,) per-adapter alpha/r_i scale.

    Returns: (T, O) LoRA delta, f32-accumulated, cast to x.dtype.
    """
    t, d = x.shape
    k_adp, _, r = a.shape
    o_dim = b.shape[2]
    tp = _ceil_to(max(t, 1), tile_t)
    if tp != t:
        x = jnp.pad(x, ((0, tp - t), (0, 0)))
        adapter_ids = jnp.pad(adapter_ids, (0, tp - t),
                              constant_values=jnp.int32(-1))
    nt = tp // tile_t
    out = pl.pallas_call(
        _fwd_kernel,
        grid=(nt, k_adp),
        in_specs=[
            pl.BlockSpec((tile_t, d), lambda i, k: (i, 0)),
            pl.BlockSpec((tile_t,), lambda i, k: (i,)),
            pl.BlockSpec((1, d, r), lambda i, k: (k, 0, 0)),
            pl.BlockSpec((1, r, o_dim), lambda i, k: (k, 0, 0)),
            pl.BlockSpec((1,), lambda i, k: (k,)),
        ],
        out_specs=pl.BlockSpec((tile_t, o_dim), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((tp, o_dim), x.dtype),
        interpret=True,
    )(x, adapter_ids, a, b, scaling.astype(jnp.float32))
    return out[:t]


# ---------------------------------------------------------------------------
# Backward kernels.
#   dx   = s_k * (g B_k^T) A_k^T           grid (token_tiles, K), like fwd
#   dA_k = s_k * (x ⊙ m_k)^T (g B_k^T)     grid (K, token_tiles), tile-acc
#   dB_k = s_k * ((x ⊙ m_k) A_k)^T g       fused with dA (shares x·mask)
# ---------------------------------------------------------------------------


def _dx_kernel(g_ref, aid_ref, a_ref, b_ref, s_ref, dx_ref):
    k = pl.program_id(1)
    g = g_ref[...]
    mask = (aid_ref[...] == k).astype(jnp.float32)[:, None]
    gm = g * mask.astype(g.dtype)
    gb = jnp.dot(gm, b_ref[0].T, preferred_element_type=jnp.float32)
    dx = jnp.dot(gb, a_ref[0].T.astype(jnp.float32),
                 preferred_element_type=jnp.float32) * s_ref[0]

    @pl.when(k == 0)
    def _init():
        dx_ref[...] = jnp.zeros_like(dx_ref)

    dx_ref[...] += dx.astype(dx_ref.dtype)


def _dab_kernel(x_ref, g_ref, aid_ref, a_ref, b_ref, s_ref, da_ref, db_ref):
    k = pl.program_id(0)
    i = pl.program_id(1)
    x = x_ref[...]
    g = g_ref[...].astype(jnp.float32)
    mask = (aid_ref[...] == k).astype(jnp.float32)[:, None]
    xm = (x * mask.astype(x.dtype)).astype(jnp.float32)
    gb = jnp.dot(g, b_ref[0].T.astype(jnp.float32),
                 preferred_element_type=jnp.float32)       # (Tt, R)
    xa = jnp.dot(xm, a_ref[0].astype(jnp.float32),
                 preferred_element_type=jnp.float32)       # (Tt, R)
    da = jnp.dot(xm.T, gb, preferred_element_type=jnp.float32) * s_ref[0]
    db = jnp.dot(xa.T, g, preferred_element_type=jnp.float32) * s_ref[0]

    @pl.when(i == 0)
    def _init():
        da_ref[...] = jnp.zeros_like(da_ref)
        db_ref[...] = jnp.zeros_like(db_ref)

    da_ref[...] += da[None].astype(da_ref.dtype)
    db_ref[...] += db[None].astype(db_ref.dtype)


def fused_lora_bwd_only(x, adapter_ids, a, b, scaling, g, *,
                        tile_t: int = DEFAULT_TILE_T):
    """Backward pass: returns (dx, da, db)."""
    t, d = x.shape
    k_adp, _, r = a.shape
    o_dim = b.shape[2]
    tp = _ceil_to(max(t, 1), tile_t)
    if tp != t:
        x = jnp.pad(x, ((0, tp - t), (0, 0)))
        g = jnp.pad(g, ((0, tp - t), (0, 0)))
        adapter_ids = jnp.pad(adapter_ids, (0, tp - t),
                              constant_values=jnp.int32(-1))
    nt = tp // tile_t
    s32 = scaling.astype(jnp.float32)

    dx = pl.pallas_call(
        _dx_kernel,
        grid=(nt, k_adp),
        in_specs=[
            pl.BlockSpec((tile_t, o_dim), lambda i, k: (i, 0)),
            pl.BlockSpec((tile_t,), lambda i, k: (i,)),
            pl.BlockSpec((1, d, r), lambda i, k: (k, 0, 0)),
            pl.BlockSpec((1, r, o_dim), lambda i, k: (k, 0, 0)),
            pl.BlockSpec((1,), lambda i, k: (k,)),
        ],
        out_specs=pl.BlockSpec((tile_t, d), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((tp, d), x.dtype),
        interpret=True,
    )(g, adapter_ids, a, b, s32)

    da, db = pl.pallas_call(
        _dab_kernel,
        grid=(k_adp, nt),
        in_specs=[
            pl.BlockSpec((tile_t, d), lambda k, i: (i, 0)),
            pl.BlockSpec((tile_t, o_dim), lambda k, i: (i, 0)),
            pl.BlockSpec((tile_t,), lambda k, i: (i,)),
            pl.BlockSpec((1, d, r), lambda k, i: (k, 0, 0)),
            pl.BlockSpec((1, r, o_dim), lambda k, i: (k, 0, 0)),
            pl.BlockSpec((1,), lambda k, i: (k,)),
        ],
        out_specs=[
            pl.BlockSpec((1, d, r), lambda k, i: (k, 0, 0)),
            pl.BlockSpec((1, r, o_dim), lambda k, i: (k, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k_adp, d, r), a.dtype),
            jax.ShapeDtypeStruct((k_adp, r, o_dim), b.dtype),
        ],
        interpret=True,
    )(x, g, adapter_ids, a, b, s32)
    return dx[:t], da, db


# ---------------------------------------------------------------------------
# Differentiable wrapper
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def fused_lora(x, adapter_ids, a, b, scaling, tile_t: int = DEFAULT_TILE_T):
    """Differentiable fused multi-adapter LoRA delta (see module docs)."""
    return fused_lora_fwd_only(x, adapter_ids, a, b, scaling, tile_t=tile_t)


def _vjp_fwd(x, adapter_ids, a, b, scaling, tile_t):
    y = fused_lora_fwd_only(x, adapter_ids, a, b, scaling, tile_t=tile_t)
    return y, (x, adapter_ids, a, b, scaling)


def _vjp_bwd(tile_t, res, g):
    x, adapter_ids, a, b, scaling = res
    dx, da, db = fused_lora_bwd_only(x, adapter_ids, a, b, scaling, g,
                                     tile_t=tile_t)
    # scaling is a hyperparameter; return symbolic zero via None-like zeros.
    ds = jnp.zeros_like(scaling)
    return dx, None, da, db, ds


fused_lora.defvjp(_vjp_fwd, _vjp_bwd)


# ---------------------------------------------------------------------------
# Unfused comparator (the "PyTorch-native kernel" of Fig. 7): one dense
# GEMM pair per adapter, materializing per-adapter temporaries and issuing
# K separate (simulated) launches. Differentiable via plain jax autodiff.
# ---------------------------------------------------------------------------


def unfused_lora(x, adapter_ids, a, b, scaling):
    """Per-adapter loop comparator. Same math, K separate GEMM pairs."""
    k_adp = a.shape[0]
    out = jnp.zeros((x.shape[0], b.shape[2]), x.dtype)
    for k in range(k_adp):  # unrolled: one "launch" per adapter
        mask = (adapter_ids == k).astype(x.dtype)[:, None]
        xk = x * mask                      # materialized gather
        inter = xk @ a[k]                  # materialized (T, R) temp
        yk = (inter @ b[k]) * scaling[k]   # materialized (T, O) temp
        out = out + yk * mask
    return out


def vmem_footprint_bytes(tile_t: int, d: int, r: int, o_dim: int,
                         dtype_bytes: int = 4) -> int:
    """Estimated VMEM residency of one fwd grid step (DESIGN.md §Perf)."""
    x_tile = tile_t * d
    a_tile = d * r
    b_tile = r * o_dim
    inter = tile_t * r
    out_tile = tile_t * o_dim
    return (x_tile + a_tile + b_tile + inter + out_tile) * dtype_bytes


def mxu_utilization_estimate(tokens_per_adapter, d: int, r_used, r_max: int,
                             o_dim: int) -> float:
    """Useful MACs / padded-tile MACs for a group of adapters.

    ``tokens_per_adapter`` and ``r_used`` are per-adapter sequences. This is
    the rank-padding efficiency of the fused kernel: the masked-accumulate
    schedule does K passes over every token tile, so utilization is
    (sum_i t_i * d * (r_i + ... )) / (K * T * d * r_max + ...).
    """
    total_tokens = float(sum(tokens_per_adapter))
    k_adp = len(r_used)
    useful = sum(t * (d * r + r * o_dim)
                 for t, r in zip(tokens_per_adapter, r_used))
    padded = k_adp * total_tokens * (d * r_max + r_max * o_dim)
    return useful / padded if padded else 0.0
