"""Pure-jnp oracle for the fused multi-LoRA kernel.

This is the correctness ground truth: a direct, obviously-correct
implementation of the multi-adapter LoRA delta. It is differentiable by
plain jax autodiff, so tests compare both forward values and gradients of
the Pallas kernel against it (python/tests/test_kernel.py).
"""

from __future__ import annotations

import jax.numpy as jnp


def lora_ref(x, adapter_ids, a, b, scaling):
    """Reference multi-adapter LoRA delta.

    For token t owned by adapter k: ``y_t = scaling[k] * x_t @ A_k @ B_k``.
    Tokens whose id is outside [0, K) produce zero.

    Shapes: x (T, D); adapter_ids (T,) int32; a (K, D, R); b (K, R, O);
    scaling (K,). Returns (T, O).
    """
    k_adp = a.shape[0]
    # (T, K) ownership one-hot; out-of-range ids give an all-zero row.
    onehot = (adapter_ids[:, None] == jnp.arange(k_adp)[None, :]).astype(
        jnp.float32)
    # Compact per-token low-rank path, batched over adapters:
    #   inter[t, k, r] = x[t] @ A_k ;  y[t, k, o] = inter @ B_k
    inter = jnp.einsum("td,kdr->tkr", x.astype(jnp.float32),
                       a.astype(jnp.float32))
    y = jnp.einsum("tkr,kro->tko", inter, b.astype(jnp.float32))
    y = y * (scaling.astype(jnp.float32))[None, :, None]
    out = jnp.einsum("tko,tk->to", y, onehot)
    return out.astype(x.dtype)


def lora_ref_grads(x, adapter_ids, a, b, scaling, g):
    """Closed-form gradients of ``sum(lora_ref * g)`` — a second oracle.

    Returns (dx, da, db) using the textbook formulas
      dB_k = s_k (X_k A_k)^T G_k ; dA_k = s_k X_k^T (G_k B_k^T) ;
      dx_t = s_k g_t B_k^T A_k^T.
    """
    k_adp = a.shape[0]
    onehot = (adapter_ids[:, None] == jnp.arange(k_adp)[None, :]).astype(
        jnp.float32)
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    s = scaling.astype(jnp.float32)
    xm = xf[None] * onehot.T[:, :, None]      # (K, T, D)
    gm = gf[None] * onehot.T[:, :, None]      # (K, T, O)
    gb = jnp.einsum("kto,kro->ktr", gm, b.astype(jnp.float32))
    xa = jnp.einsum("ktd,kdr->ktr", xm, a.astype(jnp.float32))
    da = jnp.einsum("ktd,ktr->kdr", xm, gb) * s[:, None, None]
    db = jnp.einsum("ktr,kto->kro", xa, gm) * s[:, None, None]
    dx = jnp.einsum("ktr,kdr,k->td", gb, a.astype(jnp.float32), s)
    # note: gb rows for tokens not owned by k are zero, so dx is exact.
    return dx.astype(x.dtype), da.astype(a.dtype), db.astype(b.dtype)
