"""Layer 2: the Shared Super-Model (SSM) as a JAX compute graph.

tLoRA's Model Fuser (§3.2) consolidates K LoRA fine-tuning jobs that share
one frozen backbone into a single composite model. Here that composite is
a decoder-only transformer whose q/v projections carry *stacked* LoRA
branches — one slice per job — executed by the fused Pallas kernel
(kernels/fused_lora.py). Functional equivalence with independent training
holds because:

  * the backbone is frozen (no cross-job interference through shared
    weights);
  * each token belongs to exactly one adapter, and the fused kernel's
    rank-mask gather means job i's tokens only ever touch (A_i, B_i);
  * optimizer state is sliced per adapter (stacked on the K axis), so
    updates never mix across jobs (tested in test_model.py).

Everything here runs at *build time only*: ``aot.py`` lowers ``init_fn``
and ``train_step`` to HLO text that the Rust runtime loads via PJRT.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from compile.kernels.fused_lora import fused_lora, unfused_lora


@dataclasses.dataclass(frozen=True)
class SsmConfig:
    """Static configuration of one Shared Super-Model variant."""

    name: str = "tiny"
    vocab: int = 256
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 4
    d_ff: int = 256
    seq_len: int = 32
    # --- SSM / multi-LoRA ---
    num_adapters: int = 4
    r_max: int = 8
    # per-adapter true ranks (len == num_adapters, each <= r_max)
    ranks: Tuple[int, ...] = (2, 4, 8, 8)
    lora_alpha: float = 16.0
    # sequences per adapter in one fused step (heterogeneous batch sizes)
    batch_sizes: Tuple[int, ...] = (2, 2, 2, 2)
    # --- optimizer ---
    lr: float = 1e-3
    adam_b1: float = 0.9
    adam_b2: float = 0.999
    adam_eps: float = 1e-8
    # use the fused pallas kernel (True) or the per-adapter unfused
    # comparator (False) — the Fig. 7 ablation.
    fused: bool = True
    # kernel token tile
    tile_t: int = 128

    @property
    def total_batch(self) -> int:
        return sum(self.batch_sizes)

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def scaling(self) -> jnp.ndarray:
        return jnp.asarray(
            [self.lora_alpha / r for r in self.ranks], jnp.float32)

    def rank_mask_a(self) -> jnp.ndarray:
        """(K, 1, R) mask zeroing columns past each adapter's true rank."""
        r = jnp.arange(self.r_max)[None, None, :]
        ranks = jnp.asarray(self.ranks)[:, None, None]
        return (r < ranks).astype(jnp.float32)

    def param_count(self) -> int:
        c = self.vocab * self.d_model
        per_layer = (2 * self.d_model            # ln scales
                     + 4 * self.d_model * self.d_model
                     + 2 * self.d_model * self.d_ff)
        c += self.n_layers * per_layer + self.d_model
        return c

    def lora_param_count(self) -> int:
        # q and v projections, per layer, per adapter (padded to r_max)
        per = self.d_model * self.r_max * 2          # A and B
        return self.n_layers * self.num_adapters * per * 2

    def flops_per_step(self) -> int:
        """~6 * params * tokens for fwd+bwd (backbone activations only
        need fwd+dx; adapters need full fwd+bwd). Coarse, used for
        cross-checking the Rust cost model."""
        tokens = self.total_batch * self.seq_len
        return 6 * self.param_count() * tokens


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------


def init_backbone(cfg: SsmConfig, key) -> Dict[str, jnp.ndarray]:
    """Frozen backbone parameters. Layer-stacked for lax.scan."""
    ks = jax.random.split(key, 8)
    d, f, l_num = cfg.d_model, cfg.d_ff, cfg.n_layers
    sd = d ** -0.5
    sf = f ** -0.5

    def nrm(k, shape, scale):
        return (jax.random.normal(k, shape, jnp.float32) * scale)

    return {
        "embed": nrm(ks[0], (cfg.vocab, d), 0.02),
        "ln1": jnp.ones((l_num, d), jnp.float32),
        "wq": nrm(ks[1], (l_num, d, d), sd),
        "wk": nrm(ks[2], (l_num, d, d), sd),
        "wv": nrm(ks[3], (l_num, d, d), sd),
        "wo": nrm(ks[4], (l_num, d, d), sd),
        "ln2": jnp.ones((l_num, d), jnp.float32),
        "w_in": nrm(ks[5], (l_num, d, f), sd),
        "w_out": nrm(ks[6], (l_num, f, d), sf),
        "ln_f": jnp.ones((d,), jnp.float32),
    }


def init_lora(cfg: SsmConfig, key) -> Dict[str, jnp.ndarray]:
    """Stacked LoRA branches: A ~ N(0, 1/r), B = 0 (standard LoRA init).

    Columns of A past each adapter's true rank are zeroed; this padding is
    exactly preserved by training (zero gradients — see module docs of
    fused_lora.py), so heterogeneous ranks share one static shape.
    """
    l_num, k_adp, d, r = cfg.n_layers, cfg.num_adapters, cfg.d_model, cfg.r_max
    ka, kb = jax.random.split(key)
    mask = cfg.rank_mask_a()[None]      # (1, K, 1, R)
    a_q = jax.random.normal(ka, (l_num, k_adp, d, r), jnp.float32) * (r ** -0.5)
    a_v = jax.random.normal(kb, (l_num, k_adp, d, r), jnp.float32) * (r ** -0.5)
    return {
        "a_q": a_q * mask,
        "b_q": jnp.zeros((l_num, k_adp, r, d), jnp.float32),
        "a_v": a_v * mask,
        "b_v": jnp.zeros((l_num, k_adp, r, d), jnp.float32),
    }


def init_fn(cfg: SsmConfig, seed):
    """Full state init from an int32 seed (AOT'd as `<name>.init`)."""
    key = jax.random.PRNGKey(seed)
    kb, kl = jax.random.split(key)
    backbone = init_backbone(cfg, kb)
    lora = init_lora(cfg, kl)
    opt = {
        "m": jax.tree.map(jnp.zeros_like, lora),
        "v": jax.tree.map(jnp.zeros_like, lora),
        "t": jnp.zeros((), jnp.float32),
    }
    return backbone, lora, opt


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def _rms_norm(x, scale):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * scale


def _positional(seq_len: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, (2 * (dim // 2)) / d)
    return jnp.where(dim % 2 == 0, jnp.sin(angle), jnp.cos(angle))


def _attention(q, k, v, n_heads: int):
    b, s, d = q.shape
    hd = d // n_heads

    def split(t):
        return t.reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)

    qh, kh, vh = split(q), split(k), split(v)
    logits = jnp.einsum("bhqd,bhkd->bhqk", qh, kh) / jnp.sqrt(float(hd))
    causal = jnp.tril(jnp.ones((s, s), jnp.float32))
    logits = jnp.where(causal[None, None] > 0, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vh)
    return out.transpose(0, 2, 1, 3).reshape(b, s, d)


def ssm_forward(cfg: SsmConfig, backbone, lora, tokens, adapter_ids):
    """Fused multi-job forward.

    tokens: (B, S) int32; adapter_ids: (B,) int32 — per-sequence job
    ownership (a fused batch concatenates each job's sequences).
    Returns logits (B, S, V).
    """
    b, s = tokens.shape
    d = cfg.d_model
    lora_op = fused_lora if cfg.fused else unfused_lora
    scaling = cfg.scaling()

    h = backbone["embed"][tokens] + _positional(s, d)[None]
    tok_ids = jnp.repeat(adapter_ids, s)          # (B*S,) token ownership

    def apply_lora(x, a, b_mat):
        flat = x.reshape(b * s, d)
        if cfg.fused:
            delta = lora_op(flat, tok_ids, a, b_mat, scaling, cfg.tile_t)
        else:
            delta = lora_op(flat, tok_ids, a, b_mat, scaling)
        return delta.reshape(b, s, d)

    def layer(h, layer_params):
        (ln1, wq, wk, wv, wo, ln2, w_in, w_out,
         a_q, b_q, a_v, b_v) = layer_params
        x = _rms_norm(h, ln1)
        q = x @ wq + apply_lora(x, a_q, b_q)
        k = x @ wk
        v = x @ wv + apply_lora(x, a_v, b_v)
        attn = _attention(q, k, v, cfg.n_heads)
        h = h + attn @ wo
        x2 = _rms_norm(h, ln2)
        ff = jax.nn.gelu(x2 @ w_in) @ w_out
        h = h + ff
        return h, None

    stacked = (backbone["ln1"], backbone["wq"], backbone["wk"],
               backbone["wv"], backbone["wo"], backbone["ln2"],
               backbone["w_in"], backbone["w_out"],
               lora["a_q"], lora["b_q"], lora["a_v"], lora["b_v"])
    h, _ = jax.lax.scan(lambda c, p: layer(c, p), h, stacked)
    h = _rms_norm(h, backbone["ln_f"])
    logits = h @ backbone["embed"].T        # tied lm head
    return logits


def loss_fn(cfg: SsmConfig, backbone, lora, tokens, adapter_ids):
    """Causal-LM cross entropy; returns (mean loss, per-adapter loss).

    The *training objective* is ``sum(per_adapter)`` — each job's own mean
    loss, summed. This (not the batch mean) is what makes fused training
    functionally identical to isolated training: job k's adapter gradient
    is exactly the gradient of job k's standalone objective, independent of
    which other jobs share the batch (tested in
    test_model.py::test_grouped_equals_isolated_training).
    """
    logits = ssm_forward(cfg, backbone, lora, tokens, adapter_ids)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    per_seq = jnp.mean(nll, axis=-1)                       # (B,)
    onehot = (adapter_ids[:, None] ==
              jnp.arange(cfg.num_adapters)[None, :]).astype(jnp.float32)
    seq_count = jnp.maximum(onehot.sum(axis=0), 1.0)
    per_adapter = (per_seq[:, None] * onehot).sum(axis=0) / seq_count
    return jnp.mean(per_seq), per_adapter


# ---------------------------------------------------------------------------
# Train step (adapters only; Adam)
# ---------------------------------------------------------------------------


def train_step(cfg: SsmConfig, backbone, lora, opt, tokens, adapter_ids):
    """One fused SSM training step. Backbone is frozen (no grads).

    Returns (lora', opt', loss, per_adapter_loss).
    """

    def objective(lo):
        l, per = loss_fn(cfg, backbone, lo, tokens, adapter_ids)
        # sum of per-job means: preserves isolated-training semantics
        return jnp.sum(per), (l, per)

    (_, (loss, per_adapter)), grads = jax.value_and_grad(
        objective, has_aux=True)(lora)

    t = opt["t"] + 1.0
    b1, b2, eps, lr = cfg.adam_b1, cfg.adam_b2, cfg.adam_eps, cfg.lr

    def upd(m, v, g, p):
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m2 / (1 - b1 ** t)
        vhat = v2 / (1 - b2 ** t)
        return m2, v2, p - lr * mhat / (jnp.sqrt(vhat) + eps)

    new_m, new_v, new_p = {}, {}, {}
    for name in lora:
        m2, v2, p2 = upd(opt["m"][name], opt["v"][name], grads[name],
                         lora[name])
        new_m[name], new_v[name], new_p[name] = m2, v2, p2

    opt2 = {"m": new_m, "v": new_v, "t": t}
    return new_p, opt2, loss, per_adapter


def train_step_nano(cfg: SsmConfig, backbone, lora, opt, tokens, adapter_ids,
                    n_nano: int):
    """Nano-batched train step (§3.3): the fused batch is split into
    ``n_nano`` slices along the batch dimension, gradients accumulated.

    On a real multi-GPU deployment each slice's gradient all-reduce
    overlaps the next slice's compute (Eq. 1); on the single-device AOT
    artifact this is the numerics-equivalent schedule (identical result,
    tested), while the Rust kernelsim models the comm/comp overlap.

    Exact equivalence with ``train_step`` requires each nano-slice to have
    the same per-job sequence composition (round-robin interleaving),
    which is how the coordinator lays out fused batches.
    """
    b = cfg.total_batch
    assert b % n_nano == 0, "nano count must divide fused batch"
    nb = b // n_nano

    def seg_loss(lo, seg_tokens, seg_ids):
        l, per = loss_fn(cfg, backbone, lo, seg_tokens, seg_ids)
        return jnp.sum(per), (l, per)

    zeros = jax.tree.map(jnp.zeros_like, lora)
    loss_acc = jnp.zeros(())
    per_acc = jnp.zeros((cfg.num_adapters,))
    grads_acc = zeros
    for i in range(n_nano):
        seg_t = jax.lax.dynamic_slice_in_dim(tokens, i * nb, nb, axis=0)
        seg_i = jax.lax.dynamic_slice_in_dim(adapter_ids, i * nb, nb, axis=0)
        (_, (l, per)), g = jax.value_and_grad(seg_loss, has_aux=True)(
            lora, seg_t, seg_i)
        grads_acc = jax.tree.map(jnp.add, grads_acc, g)
        loss_acc = loss_acc + l
        per_acc = per_acc + per
    grads = jax.tree.map(lambda g: g / n_nano, grads_acc)
    loss = loss_acc / n_nano
    per_adapter = per_acc / n_nano

    t = opt["t"] + 1.0
    b1, b2, eps, lr = cfg.adam_b1, cfg.adam_b2, cfg.adam_eps, cfg.lr
    new_m, new_v, new_p = {}, {}, {}
    for name in lora:
        m2 = b1 * opt["m"][name] + (1 - b1) * grads[name]
        v2 = b2 * opt["v"][name] + (1 - b2) * jnp.square(grads[name])
        mhat = m2 / (1 - b1 ** t)
        vhat = v2 / (1 - b2 ** t)
        new_m[name], new_v[name] = m2, v2
        new_p[name] = lora[name] - lr * mhat / (jnp.sqrt(vhat) + eps)
    return new_p, {"m": new_m, "v": new_v, "t": t}, loss, per_adapter


# ---------------------------------------------------------------------------
# Flattening helpers shared with aot.py (fixed argument order — the Rust
# runtime binds buffers positionally from the manifest).
# ---------------------------------------------------------------------------

BACKBONE_ORDER = ["embed", "ln1", "wq", "wk", "wv", "wo", "ln2", "w_in",
                  "w_out", "ln_f"]
LORA_ORDER = ["a_q", "b_q", "a_v", "b_v"]


def flatten_state(backbone, lora, opt) -> List[jnp.ndarray]:
    out = [backbone[n] for n in BACKBONE_ORDER]
    out += [lora[n] for n in LORA_ORDER]
    out += [opt["m"][n] for n in LORA_ORDER]
    out += [opt["v"][n] for n in LORA_ORDER]
    out.append(opt["t"])
    return out


def unflatten_state(cfg: SsmConfig, flat: List[jnp.ndarray]):
    nb, nl = len(BACKBONE_ORDER), len(LORA_ORDER)
    backbone = dict(zip(BACKBONE_ORDER, flat[:nb]))
    lora = dict(zip(LORA_ORDER, flat[nb:nb + nl]))
    m = dict(zip(LORA_ORDER, flat[nb + nl:nb + 2 * nl]))
    v = dict(zip(LORA_ORDER, flat[nb + 2 * nl:nb + 3 * nl]))
    opt = {"m": m, "v": v, "t": flat[nb + 3 * nl]}
    return backbone, lora, opt


def make_flat_train_step(cfg: SsmConfig, n_nano: int = 1):
    """Positional-args train step for AOT lowering.

    Signature: (*state_flat, tokens, adapter_ids) ->
               (lora_flat..., opt_m..., opt_v..., t, loss, per_adapter)
    """

    def flat_step(*args):
        state_flat = list(args[:-2])
        tokens, adapter_ids = args[-2], args[-1]
        backbone, lora, opt = unflatten_state(cfg, state_flat)
        if n_nano == 1:
            lora2, opt2, loss, per = train_step(
                cfg, backbone, lora, opt, tokens, adapter_ids)
        else:
            lora2, opt2, loss, per = train_step_nano(
                cfg, backbone, lora, opt, tokens, adapter_ids, n_nano)
        outs = [lora2[n] for n in LORA_ORDER]
        outs += [opt2["m"][n] for n in LORA_ORDER]
        outs += [opt2["v"][n] for n in LORA_ORDER]
        outs.append(opt2["t"])
        outs.append(loss)
        outs.append(per)
        return tuple(outs)

    return flat_step


def make_flat_init(cfg: SsmConfig):
    def flat_init(seed):
        backbone, lora, opt = init_fn(cfg, seed)
        return tuple(flatten_state(backbone, lora, opt))

    return flat_init
