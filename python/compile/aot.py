"""AOT compiler: lower SSM variants to HLO text + manifest for Rust.

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 crate binds) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Each variant emits:
  <name>.init.hlo.txt   (seed:i32) -> full state tuple
  <name>.step.hlo.txt   (*state, tokens, adapter_ids) -> (lora', opt', t,
                                                          loss, per_adapter)
plus kernel micro-bench programs (kmicro_*), and a single manifest.json
describing every program's positional buffer layout so the Rust runtime
can bind PJRT buffers without any Python at run time.

Usage:  python -m compile.aot --out-dir ../artifacts [--variants tiny,small]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.model import (SsmConfig, make_flat_init, make_flat_train_step,
                           BACKBONE_ORDER, LORA_ORDER)
from compile.kernels.fused_lora import (fused_lora_fwd_only,
                                        fused_lora_bwd_only, unfused_lora)

# ---------------------------------------------------------------------------
# Variant registry — tiny/small feed tests & CI benches, med/e2e100m feed
# fig10 and the end-to-end example. Ranks/batches are heterogeneous on
# purpose (the paper's §2 heterogeneity dimensions).
# ---------------------------------------------------------------------------

VARIANTS = {
    "tiny": SsmConfig(
        name="tiny", vocab=256, d_model=64, n_layers=2, n_heads=4, d_ff=256,
        seq_len=32, num_adapters=4, r_max=8, ranks=(2, 4, 8, 8),
        batch_sizes=(2, 2, 2, 2), tile_t=64, lr=5e-3),
    "tiny_unfused": SsmConfig(
        name="tiny_unfused", vocab=256, d_model=64, n_layers=2, n_heads=4,
        d_ff=256, seq_len=32, num_adapters=4, r_max=8, ranks=(2, 4, 8, 8),
        batch_sizes=(2, 2, 2, 2), fused=False, tile_t=64, lr=5e-3),
    "small": SsmConfig(
        name="small", vocab=2048, d_model=256, n_layers=4, n_heads=8,
        d_ff=1024, seq_len=64, num_adapters=4, r_max=16, ranks=(2, 4, 8, 16),
        batch_sizes=(1, 2, 4, 1), tile_t=128, lr=5e-3),
    "med": SsmConfig(
        name="med", vocab=8192, d_model=512, n_layers=8, n_heads=8,
        d_ff=2048, seq_len=64, num_adapters=4, r_max=16, ranks=(4, 4, 8, 16),
        batch_sizes=(1, 1, 1, 1), tile_t=128, lr=2e-3),
    "e2e100m": SsmConfig(
        name="e2e100m", vocab=16384, d_model=768, n_layers=12, n_heads=12,
        d_ff=3072, seq_len=128, num_adapters=4, r_max=16, ranks=(4, 8, 8, 16),
        batch_sizes=(1, 1, 1, 1), tile_t=128, lr=2e-3),
}

# nano-batched step programs (Fig. 8a real-numerics check): name -> (base, N)
NANO_VARIANTS = {
    "tiny_nano2": ("tiny", 2),
    "tiny_nano4": ("tiny", 4),
}

# kernel micro programs: (fused?, K adapters)
KMICRO = [(True, 1), (False, 1), (True, 4), (False, 4), (True, 16),
          (False, 16)]
KMICRO_T, KMICRO_D, KMICRO_R = 512, 256, 16

DEFAULT_VARIANT_SET = ["tiny", "tiny_unfused", "small", "med", "e2e100m"]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _dtype_name(dt) -> str:
    return {"float32": "f32", "int32": "i32", "bfloat16": "bf16",
            "float16": "f16"}[jnp.dtype(dt).name]


def _spec_list(shapes) -> list:
    return [{"shape": list(s.shape), "dtype": _dtype_name(s.dtype)}
            for s in shapes]


def _state_specs(cfg: SsmConfig):
    """ShapeDtypeStructs of the flattened state, in manifest order."""
    init = make_flat_init(cfg)
    out = jax.eval_shape(init, jax.ShapeDtypeStruct((), jnp.int32))
    return list(out)


def lower_variant(cfg: SsmConfig, out_dir: str, n_nano: int = 1,
                  name: str | None = None) -> dict:
    name = name or cfg.name
    state = _state_specs(cfg)
    tokens = jax.ShapeDtypeStruct((cfg.total_batch, cfg.seq_len), jnp.int32)
    aid = jax.ShapeDtypeStruct((cfg.total_batch,), jnp.int32)

    entry = {"name": name, "n_nano": n_nano,
             "config": dataclasses.asdict(cfg),
             "param_count": cfg.param_count(),
             "lora_param_count": cfg.lora_param_count(),
             "flops_per_step": cfg.flops_per_step(),
             "state_layout": {
                 "backbone": BACKBONE_ORDER, "lora": LORA_ORDER,
                 "n_backbone": len(BACKBONE_ORDER),
                 "n_lora": len(LORA_ORDER)}}

    if n_nano == 1:
        init = make_flat_init(cfg)
        init_lowered = jax.jit(init).lower(
            jax.ShapeDtypeStruct((), jnp.int32))
        init_file = f"{name}.init.hlo.txt"
        with open(os.path.join(out_dir, init_file), "w") as f:
            f.write(to_hlo_text(init_lowered))
        entry["init"] = {
            "file": init_file,
            "inputs": [{"shape": [], "dtype": "i32"}],
            "outputs": _spec_list(state)}

    step = make_flat_train_step(cfg, n_nano=n_nano)
    step_args = state + [tokens, aid]
    step_lowered = jax.jit(step).lower(*step_args)
    step_out = jax.eval_shape(step, *step_args)
    step_file = f"{name}.step.hlo.txt"
    with open(os.path.join(out_dir, step_file), "w") as f:
        f.write(to_hlo_text(step_lowered))
    entry["step"] = {"file": step_file,
                     "inputs": _spec_list(step_args),
                     "outputs": _spec_list(list(step_out))}
    return entry


def lower_kmicro(fused: bool, k_adp: int, out_dir: str) -> dict:
    """Standalone fused-vs-unfused kernel program: fwd + full backward."""
    t, d, r = KMICRO_T, KMICRO_D, KMICRO_R

    def prog(x, aid, a, b, scaling):
        if fused:
            y = fused_lora_fwd_only(x, aid, a, b, scaling)
            dx, da, db = fused_lora_bwd_only(x, aid, a, b, scaling, y)
        else:
            y = unfused_lora(x, aid, a, b, scaling)
            _, vjp = jax.vjp(
                lambda xx, aa, bb: unfused_lora(xx, aid, aa, bb, scaling),
                x, a, b)
            dx, da, db = vjp(y)
        return y, dx, da, db

    args = [jax.ShapeDtypeStruct((t, d), jnp.float32),
            jax.ShapeDtypeStruct((t,), jnp.int32),
            jax.ShapeDtypeStruct((k_adp, d, r), jnp.float32),
            jax.ShapeDtypeStruct((k_adp, r, d), jnp.float32),
            jax.ShapeDtypeStruct((k_adp,), jnp.float32)]
    lowered = jax.jit(prog).lower(*args)
    outs = jax.eval_shape(prog, *args)
    kind = "fused" if fused else "unfused"
    name = f"kmicro_{kind}_k{k_adp}"
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(to_hlo_text(lowered))
    flops = 2 * 2 * t * d * r * 2 * (k_adp if not fused else k_adp)
    return {"name": name, "file": fname, "fused": fused, "k": k_adp,
            "t": t, "d": d, "r": r,
            "inputs": _spec_list(args),
            "outputs": _spec_list(list(outs)),
            "flops_est": flops}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--variants", default=",".join(DEFAULT_VARIANT_SET),
                    help="comma-separated variant names, 'all', or 'ci'")
    ap.add_argument("--skip-kmicro", action="store_true")
    ap.add_argument("--skip-nano", action="store_true")
    args = ap.parse_args()

    if args.variants == "all":
        names = DEFAULT_VARIANT_SET
    elif args.variants == "ci":
        names = ["tiny", "tiny_unfused", "small"]
    else:
        names = [n for n in args.variants.split(",") if n]

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"format": 1, "variants": [], "kmicro": [], "nano": []}

    for n in names:
        cfg = VARIANTS[n]
        print(f"[aot] lowering variant {n} "
              f"(params={cfg.param_count() / 1e6:.1f}M)", flush=True)
        manifest["variants"].append(lower_variant(cfg, args.out_dir))

    if not args.skip_nano:
        for name, (base, n_nano) in NANO_VARIANTS.items():
            if base in names:
                print(f"[aot] lowering nano variant {name}", flush=True)
                manifest["nano"].append(
                    lower_variant(VARIANTS[base], args.out_dir,
                                  n_nano=n_nano, name=name))

    if not args.skip_kmicro:
        for fused, k_adp in KMICRO:
            print(f"[aot] lowering kmicro fused={fused} k={k_adp}",
                  flush=True)
            manifest["kmicro"].append(lower_kmicro(fused, k_adp,
                                                   args.out_dir))

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {mpath}")


if __name__ == "__main__":
    main()
