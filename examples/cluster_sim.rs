//! Trace-driven 128-GPU cluster simulation (§4.2 headline numbers):
//! replays a synthetic ACMETrace-style workload under all five policies
//! and prints throughput / JCT / utilization — the `compare` subcommand
//! as a runnable example.
//!
//! ```sh
//! cargo run --release --example cluster_sim -- [--n-jobs 120] \
//!     [--n-gpus 128] [--seed 42] [--month 1]
//! ```

use tlora::cli::Args;
use tlora::config::{ExperimentConfig, Policy};
use tlora::metrics::Table;
use tlora::sim::simulate;
use tlora::workload::trace::TraceProfile;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let refs: Vec<&str> = argv.iter().map(String::as_str).collect();
    let args = Args::parse_from(&refs).map_err(anyhow::Error::msg)?;

    let mut cfg = ExperimentConfig::default();
    cfg.n_jobs = args.get_usize("n-jobs", 120).map_err(anyhow::Error::msg)?;
    cfg.cluster = tlora::cluster::ClusterSpec::with_gpus(
        args.get_usize("n-gpus", 128).map_err(anyhow::Error::msg)?,
    );
    cfg.seed = args.get_u64("seed", 42).map_err(anyhow::Error::msg)?;
    cfg.trace = match args.get_usize("month", 1).unwrap_or(1) {
        2 => TraceProfile::month2(),
        3 => TraceProfile::month3(),
        _ => TraceProfile::month1(),
    };

    let mut table = Table::new(
        &format!(
            "cluster simulation — {} jobs, {} GPUs, month-1 trace",
            cfg.n_jobs,
            cfg.cluster.total_gpus()
        ),
        &["policy", "throughput (samples/s)", "mean JCT (s)",
          "p99 JCT (s)", "GPU util"],
    );

    let mut tlora_thr = 0.0;
    let mut mlora_thr = 0.0;
    let mut tlora_jct = 0.0;
    let mut mlora_jct = 0.0;
    for policy in Policy::all() {
        let mut c = cfg.clone();
        c.policy = policy;
        let r = simulate(&c);
        match policy {
            Policy::TLora => {
                tlora_thr = r.avg_throughput;
                tlora_jct = r.mean_jct;
            }
            Policy::MLora => {
                mlora_thr = r.avg_throughput;
                mlora_jct = r.mean_jct;
            }
            _ => {}
        }
        table.row(&[
            policy.name().to_string(),
            format!("{:.2}", r.avg_throughput),
            format!("{:.1}", r.mean_jct),
            format!("{:.1}", r.p99_jct),
            format!("{:.1}%", r.avg_gpu_util * 100.0),
        ]);
    }
    table.print();

    println!(
        "\ntLoRA vs mLoRA: throughput {:.2}x (paper: 1.2-1.8x), \
         mean JCT {:.2}x better (paper: 2.3-5.4x)",
        tlora_thr / mlora_thr,
        mlora_jct / tlora_jct
    );
    Ok(())
}
