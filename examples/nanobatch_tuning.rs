//! The AIMD nano-batch controller in action (§3.3, Eq. 2).
//!
//! Simulates a fused group whose interconnect bandwidth changes
//! mid-run (e.g. a contending tenant appears): the controller re-adapts
//! the nano-batch count online, tracking the moving optimum that a
//! static configuration would miss.
//!
//! ```sh
//! cargo run --release --example nanobatch_tuning
//! ```

use tlora::config::AimdConfig;
use tlora::kernelsim::overlap::{best_fixed_n, iter_time};
use tlora::kernelsim::AimdController;

fn main() {
    let comp = 1.0; // seconds of compute per step
    let oh = 0.004; // per-nano launch overhead
    let lat = 0.001; // per-message latency

    // phase 1: fast network (little comm), phase 2: congested (lots)
    let phases = [(0.3, 150usize), (1.2, 150usize)];

    let mut ctl = AimdController::new(AimdConfig::default());
    println!("== AIMD nano-batch adaptation under changing bandwidth ==");
    println!("{:>5} {:>6} {:>5} {:>9} {:>9} {:>7}",
             "step", "comm", "N", "t_step", "t_best", "regret");

    let mut step = 0usize;
    for &(comm, len) in &phases {
        let (best_n, best_t) = best_fixed_n(comp, comm, 64, oh, lat);
        for i in 0..len {
            let n = ctl.n();
            let t = iter_time(comp, comm, n, oh, lat);
            if i % 25 == 0 {
                println!(
                    "{step:>5} {comm:>6.2} {n:>5} {t:>9.4} {best_t:>9.4} \
                     {:>6.1}%",
                    (t / best_t - 1.0) * 100.0
                );
            }
            ctl.observe(t);
            step += 1;
        }
        let tail_n = ctl.n();
        let tail_t = iter_time(comp, comm, tail_n, oh, lat);
        println!(
            "-- phase end: comm={comm:.2}s  AIMD N={tail_n} \
             (t={tail_t:.4})  oracle N={best_n} (t={best_t:.4})  \
             regret {:.1}%",
            (tail_t / best_t - 1.0) * 100.0
        );
    }
    println!(
        "\nAIMD tracked both regimes with no cost model — the paper's \
         argument for feedback-driven adaptation (Fig. 8a)."
    );
}
