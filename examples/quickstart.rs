//! Quickstart: the whole three-layer stack in one minute.
//!
//! Loads the AOT-compiled `tiny` Shared Super-Model (4 heterogeneous
//! LoRA jobs fused on one frozen backbone — Pallas fused kernel inside),
//! runs a handful of real fused training steps on the PJRT CPU client,
//! and prints the per-job losses.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use tlora::runtime::{Runtime, Trainer};
use tlora::train::data::SyntheticCorpus;

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::Path::new("artifacts");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(2);
    }

    println!("== tLoRA quickstart ==");
    println!("loading artifacts + PJRT CPU client…");
    let runtime = Runtime::new(artifacts)?;
    let mut trainer = Trainer::new(&runtime, "tiny", 0)?;
    let cfg = trainer.variant().config.clone();
    println!(
        "SSM: {} adapters (ranks {:?}, batches {:?}) on a {}-layer \
         d={} backbone",
        cfg.num_adapters, cfg.ranks, cfg.batch_sizes, cfg.n_layers,
        cfg.d_model
    );

    let mut corpus =
        SyntheticCorpus::new(cfg.vocab, cfg.seq_len, cfg.num_adapters, 1);
    println!("\nstep |   loss | per-job losses");
    for step in 0..25 {
        let (tokens, ids) = corpus.fused_batch(&cfg.batch_sizes);
        let stats = trainer.step(&tokens, &ids)?;
        if step % 5 == 0 || step == 24 {
            let per: Vec<String> = stats
                .per_adapter_loss
                .iter()
                .map(|l| format!("{l:.3}"))
                .collect();
            println!("{step:>4} | {:>6.4} | {}", stats.loss,
                     per.join("  "));
        }
    }
    println!("\nall layers composed: Pallas kernel → JAX SSM → PJRT → Rust");
    Ok(())
}
