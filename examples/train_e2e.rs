//! End-to-end validation driver (DESIGN.md §7): trains a ~100M-parameter
//! transformer SSM with 4 heterogeneous LoRA jobs — different ranks,
//! different step budgets — fused into one model, for a few hundred
//! steps on a synthetic corpus, on the PJRT CPU client via the
//! coordinator's leader/executor topology. Logs the loss curves that
//! EXPERIMENTS.md records.
//!
//! Jobs retire independently when their budgets complete (the *elastic*
//! SSM: remaining jobs keep training, retired slots are masked).
//!
//! ```sh
//! cargo run --release --example train_e2e -- \
//!     [--variant e2e100m] [--steps 300] [--scale small] [--seed 0]
//! ```
//!
//! On a 1-core CI box the 100M model takes ~seconds/step; use
//! `--variant small` (default here) for a quick pass and
//! `--variant e2e100m --steps 300` for the full paper-scale run.

use tlora::cli::Args;
use tlora::coordinator::{run_fused_jobs, Coordinator, FusedJob};

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let refs: Vec<&str> = argv.iter().map(String::as_str).collect();
    let args = Args::parse_from(&refs).map_err(anyhow::Error::msg)?;

    let variant = args
        .get_or(
            "variant",
            if args.has("full") { "e2e100m" } else { "small" },
        )
        .to_string();
    let steps = args.get_u64("steps", 200).map_err(anyhow::Error::msg)?;
    let seed = args.get_u64("seed", 0).map_err(anyhow::Error::msg)?;

    println!("== tLoRA end-to-end training ({variant}) ==");
    let artifacts = std::path::PathBuf::from(
        args.get_or("artifacts", "artifacts"),
    );
    println!("spawning coordinator (leader + PJRT executor thread)…");
    let t0 = std::time::Instant::now();
    let coord = Coordinator::spawn(artifacts, variant.clone(), seed as i32)?;
    let info = coord.variant_info()?;
    println!(
        "compiled in {:.1}s — K={} adapters, batch={:?}, seq_len={}",
        t0.elapsed().as_secs_f64(),
        info.num_adapters,
        info.batch_sizes,
        info.seq_len
    );

    // four jobs with heterogeneous step budgets: the smallest finishes
    // first and its slot retires while the rest keep training
    let jobs: Vec<FusedJob> = (0..info.num_adapters)
        .map(|slot| FusedJob {
            adapter_slot: slot,
            steps: steps * (slot as u64 + 1) / info.num_adapters as u64,
        })
        .collect();
    println!("\njob budgets: {:?}",
             jobs.iter().map(|j| j.steps).collect::<Vec<_>>());

    let report = run_fused_jobs(&coord, &jobs, seed ^ 0xE2E, 10)?;

    println!("\nfused step | per-job losses");
    for (step, per) in &report.loss_log {
        let cells: Vec<String> =
            per.iter().map(|l| format!("{l:.3}")).collect();
        println!("{step:>10} | {}", cells.join("  "));
    }
    println!("\njob results:");
    let mut improved = 0;
    let first: &Vec<f32> = &report.loss_log.first().unwrap().1;
    for &(slot, steps_done, final_loss) in &report.jobs {
        let start = first[slot];
        println!(
            "  job {slot}: {steps_done} steps, loss {start:.3} -> \
             {final_loss:.3}"
        );
        if final_loss < start {
            improved += 1;
        }
    }
    println!(
        "\nfused steps: {}  mean step: {:.0} ms  ({:.1} min total)",
        report.fused_steps,
        report.mean_step_s * 1e3,
        report.fused_steps as f64 * report.mean_step_s / 60.0
    );
    println!("{improved}/{} jobs improved their loss", report.jobs.len());
    coord.shutdown();
    if improved == 0 {
        anyhow::bail!("no job improved — training broken");
    }
    Ok(())
}
